"""Duplicate-work race matrix for fleet-wide single-flight execution
(``fabric/leases.py``): simultaneous duplicate submissions resolve to
exactly ONE lease owner (deterministic bus-order tiebreak); adoptees
receive the owner's in-flight stream bit-identically with zero local
I/O; owner death, policy ban, and mid-stream epoch bumps force fallback
without ever serving stale or losing a final; seeded drops and
partition+heal never yield two scans AND never lose a final.  Plus the
operational satellites: L2 persistence across a fleet restart and the
re-replication transfer charge in the virtual time model.

Seeds come from ``LEASE_SEEDS`` (comma-separated, default 101,202,303)
so the CI lease-matrix job can pin one seed per shard.
"""
import os

import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.brick import create_store
from repro.fabric import (Fleet, FragmentRegistry, LeaseManager, MessageBus,
                          lease_key, lease_ttl)
from repro.service.scheduler import QueryScheduler, make_submission

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)
LEASE_SEEDS = tuple(int(s) for s in os.environ.get(
    "LEASE_SEEDS", "101,202,303").split(","))

Q = "e_total > 40 && count(pt > 15) >= 2"
EXPRS = [Q,
         "e_t_miss > 30",
         "pt_lead > 60 || n_tracks >= 8"]


def make_store(n_events=192, n_nodes=4, replication=2, seed=7):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=replication, seed=seed)


def make_fleet(store, n=4, **kw):
    kw.setdefault("registry", FragmentRegistry())
    kw.setdefault("single_flight", True)
    return Fleet(store, n, **kw)


def snapshots_identical(a, b):
    return (a.seq == b.seq and a.final == b.final
            and a.t_virtual == b.t_virtual and a.coverage == b.coverage
            and merge_lib.results_identical(a.result, b.result))


def baseline_results(store, exprs_by_fe, n=4, **kw):
    """The lease-disabled reference run: same workload, same fleet shape,
    ``single_flight=False``.  Returns (per-ticket final results in
    submission order, per-ticket final stream snapshots, fleet stats)."""
    fleet = Fleet(store, n, registry=FragmentRegistry(),
                  single_flight=False, **kw)
    tids = [fleet.submit(e, frontend=i % n, stream=True)
            for i, e in enumerate(exprs_by_fe)]
    fleet.drain()
    finals = [fleet.result(t).result for t in tids]
    snaps = [fleet.stream(t).latest() for t in tids]
    stats = fleet.fleet_stats()
    fleet.close()
    return finals, snaps, stats


# ------------------------- lease protocol unit -------------------------- #
def _mgr(bus, node_id, vv=None, **kw):
    bus.register(node_id)
    return LeaseManager(node_id, bus, lambda: dict(vv or {}), **kw)


def test_lease_key_embeds_canonical_calib_and_vv_fingerprint():
    k = lease_key("expr", 3, {"fe0": 2, "fe1": 0})
    assert k == "lease:expr|c3|fe0:2"  # zero entries dropped
    assert lease_key("expr", 3, {"fe0": 2}) == k
    assert lease_key("expr", 0, {"fe0": 2}) != k
    assert lease_key("expr", 3, {"fe0": 3}) != k


def test_lease_ttl_tracks_gossip_bound_and_bus_delay():
    assert lease_ttl(4, 2, 0) > 0
    assert lease_ttl(16, 1, 0) > lease_ttl(4, 1, 0)
    assert lease_ttl(4, 2, 3) == lease_ttl(4, 2, 0) + 6


def test_same_round_intents_tiebreak_on_node_id():
    bus = MessageBus()
    a, b = _mgr(bus, "fe0"), _mgr(bus, "fe1")
    ka = a.announce("q", 0)
    kb = b.announce("q", 0)
    assert ka == kb
    bus.tick()
    for m in (a, b):
        for env in bus.recv(m.node_id):
            m.on_message(env.payload)
    # both tables agree: fe0 wins the same-round race deterministically
    assert a.holder(ka) == "fe0"
    assert b.holder(kb) == "fe0"


def test_earlier_round_beats_lower_node_id():
    bus = MessageBus()
    a, b = _mgr(bus, "fe0"), _mgr(bus, "fe1")
    kb = b.announce("q", 0)
    bus.tick()
    for env in bus.recv("fe0"):
        a.on_message(env.payload)
    ka = a.announce("q", 0)  # later round: loses despite lower node id
    assert a.holder(ka) == "fe1"
    assert b.holder(kb) == "fe1"


def test_lease_expires_when_refreshes_stop():
    bus = MessageBus()
    a, b = _mgr(bus, "fe0", ttl=2), _mgr(bus, "fe1", ttl=2)
    k = a.announce("q", 0)
    bus.tick()
    for env in bus.recv("fe1"):
        b.on_message(env.payload)
    assert b.holder(k) == "fe0"
    for _ in range(4):  # fe0 never re-emits: the lease goes stale
        bus.tick()
        bus.recv("fe1")
    assert b.holder(k) is None
    assert b.stats.expired == 1


def test_refreshes_keep_lease_fresh_and_never_improve_priority():
    bus = MessageBus()
    a, b = _mgr(bus, "fe0", ttl=3), _mgr(bus, "fe1", ttl=3)
    k = a.announce("q", 0)
    r0 = a._table[k].round
    for _ in range(10):
        a.emit()
        bus.tick()
        for env in bus.recv("fe1"):
            b.on_message(env.payload)
        bus.recv("fe0")
    assert b.holder(k) == "fe0"
    assert b._table[k].round == r0  # re-announcements carry ORIGINAL round


def test_stale_epoch_lease_is_invisible_and_intent_gcd():
    bus = MessageBus()
    vv = {"fe0": 1}
    a = _mgr(bus, "fe0", vv=vv)
    b_vv = {"fe0": 1}
    bus.register("fe1")
    b = LeaseManager("fe1", bus, lambda: dict(b_vv))
    k = a.announce("q", 0)
    bus.tick()
    for env in bus.recv("fe1"):
        b.on_message(env.payload)
    assert b.holder(k) == "fe0"
    b_vv["fe0"] = 2  # epoch bump observed by the adoptee
    assert b.holder(k) is None  # record survives but is unusable
    # the owner's own stale-fp intent is garbage-collected on emit
    vv["fe0"] = 2
    a.emit()
    assert a.intents() == []


def test_release_drops_table_and_marks_peer_release():
    bus = MessageBus()
    a, b = _mgr(bus, "fe0"), _mgr(bus, "fe1")
    k = a.announce("q", 0)
    bus.tick()
    for env in bus.recv("fe1"):
        b.on_message(env.payload)
    a.export(k, object())
    a.release(k)
    bus.tick()
    for env in bus.recv("fe1"):
        b.on_message(env.payload)
    assert b.holder(k) is None
    assert b.released_recently(k)  # owner FINISHED: wait, don't fall back
    assert k in a.exports  # export stays readable for late subs
    for _ in range(a.ttl + 2):
        bus.tick()
        a.emit()
        b.emit()
        bus.recv("fe0"), bus.recv("fe1")
    assert k not in a.exports  # GC'd one TTL after release
    assert not b.released_recently(k)


def test_revoke_drops_owner_leases_fleet_wide():
    bus = MessageBus()
    a, b, c = _mgr(bus, "fe0"), _mgr(bus, "fe1"), _mgr(bus, "fe2")
    k = a.announce("q", 0)
    bus.tick()
    for m in (b, c):
        for env in bus.recv(m.node_id):
            m.on_message(env.payload)
    assert b.holder(k) == "fe0" and c.holder(k) == "fe0"
    b.revoke_owner("fe0")  # policy ban applied by fe1
    assert b.holder(k) is None and b.stats.revoked == 1
    bus.tick()
    for env in bus.recv("fe2"):
        c.on_message(env.payload)
    assert c.holder(k) is None  # the revoke broadcast reached fe2


# --------------------------- race matrix -------------------------------- #
def test_simultaneous_duplicates_one_lease_one_scan_bit_identical():
    """N same-window duplicate submissions: exactly one front-end
    acquires the lease (fe0 — deterministic bus-order tiebreak), scans
    once, and every adoptee's final is bit-identical to the
    lease-disabled run."""
    store = make_store()
    base_finals, base_snaps, base_stats = baseline_results(
        store, [Q] * 4)
    fleet = make_fleet(make_store(), 4)
    tids = [fleet.submit(Q, frontend=i, stream=True) for i in range(4)]
    fleet.drain()
    scanned = [fe.service.stats.events_scanned for fe in fleet.frontends]
    assert scanned[0] > 0 and scanned[1:] == [0, 0, 0]
    s = fleet.fleet_stats()
    assert s["adopted"] == 3 and s["served"] == 4
    assert s["events_scanned"] * 4 == base_stats["events_scanned"]
    for i, t in enumerate(tids):
        r = fleet.result(t)
        assert r.status == "SERVED"
        assert r.adopted == (i != 0)
        assert merge_lib.results_identical(r.result, base_finals[i])
        assert snapshots_identical(fleet.stream(t).latest(), base_snaps[i])
    # every adoptee's FULL stream mirrors the owner's, snapshot by snapshot
    owner = fleet.stream(tids[0]).buffered()
    for t in tids[1:]:
        got = fleet.stream(t).buffered()
        assert len(got) == len(owner)
        assert all(snapshots_identical(x, y) for x, y in zip(got, owner))
    fleet.close()


def test_adoptee_dispatching_first_parks_sub_then_streams_live():
    """The adoptee's window can dispatch BEFORE the owner's: its sub is
    parked at the owner (never aborted) and served live from the scan's
    first packet once the owner dispatches."""
    store = make_store()
    fleet = make_fleet(store, 2)
    t0 = fleet.submit(Q, frontend=0, stream=True)
    t1 = fleet.submit(Q, frontend=1, stream=True)
    fleet.pump(2)
    assert fleet.step(frontend=1) == []  # fe1 adopts instead of scanning
    assert fleet.frontends[1].service.adoptions_pending
    fleet.pump(2)  # sub arrives at fe0 pre-dispatch: parked
    assert any(fleet.frontends[0].fanout._pending_subs.values())
    fleet.drain()
    assert fleet.frontends[1].service.stats.events_scanned == 0
    a, b = fleet.stream(t0), fleet.stream(t1)
    assert b.done and a.published == b.published
    assert all(snapshots_identical(x, y)
               for x, y in zip(a.buffered(), b.buffered()))
    assert merge_lib.results_identical(fleet.result(t0).result,
                                       fleet.result(t1).result)
    fleet.close()


def test_owner_death_mid_adoption_falls_back_to_rescan_bit_identical():
    store = make_store()
    base_finals, base_snaps, _ = baseline_results(store, [Q, Q], n=2)
    fleet = make_fleet(make_store(), 2)
    fleet.submit(Q, frontend=0, stream=True)
    t1 = fleet.submit(Q, frontend=1, stream=True)
    fleet.pump(2)
    fleet.step(frontend=1)  # fe1 adopts fe0's lease
    fleet.frontend_leave(0)  # owner dies before ever scanning
    fleet.drain()
    fe1 = fleet.frontends[1]
    assert fe1.leases.stats.expired >= 1       # TTL fired
    assert fe1.service.stats.lease_fallbacks == 1
    assert fe1.service.stats.events_scanned > 0  # fell back to own scan
    r = fleet.result(t1)
    assert r.status == "SERVED" and not r.adopted
    assert merge_lib.results_identical(r.result, base_finals[1])
    assert snapshots_identical(fleet.stream(t1).latest(), base_snaps[1])
    fleet.close()


def test_policy_ban_mid_adoption_falls_back_without_waiting_ttl():
    store = make_store()
    base_finals, _, _ = baseline_results(store, [Q, Q], n=2)
    fleet = make_fleet(make_store(), 2)
    fleet.submit(Q, frontend=0, stream=True)
    t1 = fleet.submit(Q, frontend=1, stream=True)
    fleet.pump(2)
    fleet.step(frontend=1)
    fleet.ban_frontend(0, by=1)  # revoke: no TTL wait
    fleet.drain()
    fe1 = fleet.frontends[1]
    assert fe1.leases.stats.revoked >= 1
    # the FAST path: the revoke dropped the lease, not a TTL expiry —
    # a silent crash of the same owner would have shown expired >= 1
    assert fe1.leases.stats.expired == 0
    assert fe1.service.stats.lease_fallbacks == 1
    r = fleet.result(t1)
    assert r.status == "SERVED"
    assert merge_lib.results_identical(r.result, base_finals[1])
    fleet.close()


def test_epoch_bump_mid_adoption_never_serves_stale():
    store = make_store()
    fleet = make_fleet(store, 2)
    fleet.submit(Q, frontend=0, stream=True)
    t1 = fleet.submit(Q, frontend=1, stream=True)
    fleet.pump(2)
    fleet.step(frontend=1)  # fe1 adopts under the pre-bump fingerprint
    fleet.bump_dataset_version(1)  # the adoptee's own epoch moves
    fleet.drain()
    fe1 = fleet.frontends[1]
    assert fe1.service.stats.lease_fallbacks >= 1
    r = fleet.result(t1)
    # resolved by fe1's OWN post-bump scan, never the stale-epoch stream
    assert r.status == "SERVED" and not r.adopted
    assert fe1.service.stats.events_scanned > 0
    ref = baseline_results(make_store(), [Q], n=1)[0][0]
    assert merge_lib.results_identical(r.result, ref)
    fleet.close()


@pytest.mark.parametrize("seed", LEASE_SEEDS)
def test_seeded_drops_never_two_scans_never_lose_a_final(seed):
    """Lossy bus: once the lease tables agree pre-dispatch, drops can
    delay snapshots and finals but must never cause a second scan of the
    same canonical NOR a lost final (resubscribe replay and the shared
    cache close every gap)."""
    store = make_store()
    base_finals, _, _ = baseline_results(store, [Q] * 4)
    fleet = make_fleet(make_store(), 4,
                       bus=MessageBus(drop_rate=0.3, seed=seed))
    tids = [fleet.submit(Q, frontend=i, stream=True) for i in range(4)]
    canonical = make_submission(0, "x", Q, 0, SCHEMA).canonical
    key = fleet.frontends[0].leases.key_for(canonical, 0)
    # pump until every member agrees fe0 owns the key (re-announcement
    # beats drops), THEN dispatch
    for _ in range(200):
        if all(fe.leases.holder(key) == "fe0" for fe in fleet.frontends):
            break
        fleet.pump()
    assert all(fe.leases.holder(key) == "fe0" for fe in fleet.frontends)
    fleet.drain()
    scanned = [fe.service.stats.events_scanned for fe in fleet.frontends]
    assert scanned[0] == store.n_events and scanned[1:] == [0, 0, 0]
    for i, t in enumerate(tids):
        r = fleet.result(t)
        assert r.status == "SERVED"
        assert merge_lib.results_identical(r.result, base_finals[i])
    fleet.close()


@pytest.mark.parametrize("seed", LEASE_SEEDS)
def test_partition_mid_stream_heals_without_double_scan_or_lost_final(seed):
    """Partition the owner away AFTER adoption, let it scan into the
    void, heal: the adoptees must still resolve every final bit-
    identically — via late replay or the shared cache — and the
    canonical is never scanned twice."""
    store = make_store(seed=seed)
    base_finals, _, _ = baseline_results(store, [Q] * 4)
    fleet = make_fleet(make_store(seed=seed), 4)
    tids = [fleet.submit(Q, frontend=i, stream=True) for i in range(4)]
    fleet.pump(2)
    for i in (1, 2, 3):
        fleet.step(frontend=i)  # all three adopt fe0's lease
    assert all(fleet.frontends[i].service.adoptions_pending
               for i in (1, 2, 3))
    fleet.bus.partition(["fe0"], ["fe1", "fe2", "fe3"])
    fleet.step(frontend=0)  # the owner scans mid-partition
    fleet.pump(2)           # snapshots/finals/release all dropped
    fleet.bus.heal()
    fleet.drain()
    scanned = [fe.service.stats.events_scanned for fe in fleet.frontends]
    assert sum(scanned) == store.n_events  # never two scans
    for i, t in enumerate(tids):
        r = fleet.result(t)
        assert r.status == "SERVED"  # never lose a final
        assert merge_lib.results_identical(r.result, base_finals[i])
    fleet.close()


def test_fragment_leases_exported_and_adoptable_bit_identically():
    """A window that materializes a shared fragment exports one lease
    stream per fragment: a peer can subscribe through the fan-out and
    receive the fragment's full prefix + final, bit-identical to the
    owner's merged fragment result, with zero I/O of its own."""
    store = make_store()
    fleet = make_fleet(store, 2)
    # two queries sharing the conjunct -> the planner materializes it
    fleet.submit(f"{Q} && e_t_miss > 30", frontend=0)
    fleet.submit(f"{Q} && n_tracks >= 8", frontend=0)
    fe0 = fleet.frontends[0]
    fleet.step(frontend=0)
    frag_keys = [k for k in fe0.leases.exports if k.startswith("lease:")]
    # query leases + at least one materialized-fragment lease
    assert len(frag_keys) >= 3
    # find a fragment export (not one of the two query canonicals)
    subs_canon = set()
    for t in list(fe0.service.tickets.values()):
        subs_canon.add(fe0.leases.key_for(
            make_submission(0, "x", t.expr, t.calib_iters, SCHEMA,
                            n_events=store.n_events).canonical, 0))
    frag = [k for k in frag_keys if k not in subs_canon]
    assert frag, "no fragment lease exported"
    fkey = frag[0]
    export = fe0.leases.exports[fkey]
    assert export.done  # fragment stream finished with the window
    proxy = fleet.frontends[1].fanout.proxy(fkey, "fe0")
    fleet.pump(3)
    assert proxy.done  # adopted with zero I/O on fe1
    assert snapshots_identical(proxy.latest(), export.latest())
    assert all(snapshots_identical(x, y)
               for x, y in zip(proxy.buffered(), export.buffered()))
    # and the materialized conjunct is an L2 entry now: a LATER bare
    # submission of it anywhere in the fleet is a zero-I/O cache hit
    frag_expr = fkey[len("lease:"):fkey.rindex("|c")]
    fleet.drain()
    scanned_before = fleet.frontends[1].service.stats.events_scanned
    t = fleet.submit(frag_expr, frontend=1)
    fleet.drain()
    r = fleet.result(t)
    assert r.status == "SERVED" and r.from_cache
    assert fleet.frontends[1].service.stats.events_scanned == scanned_before
    fleet.close()


def test_adopted_submission_costs_zero_against_window_budget():
    """A submission another front-end holds a fresh lease on is adopted,
    not scanned — so it must not consume the window's cost budget."""
    class OneRemoteLease:
        node_id = "fe9"

        def __init__(self, canonical):
            self.canonical = canonical

        def remote_holder(self, canonical, calib_iters):
            return "fe0" if canonical == self.canonical else None

    a = make_submission(1, "t1", EXPRS[0], 0, SCHEMA, n_events=192)
    b = make_submission(2, "t2", EXPRS[1], 0, SCHEMA, n_events=192)
    # budget fits the first submission plus half the second: only a
    # free (adopted) second submission can ride along
    budget = a.cost + 0.5 * b.cost

    sched = QueryScheduler(window_cost_budget=budget)
    sched.enqueue(a), sched.enqueue(b)
    assert len(sched.next_batch()) == 1  # no leases: budget caps at one

    sched = QueryScheduler(window_cost_budget=budget)
    sched.leases = OneRemoteLease(b.canonical)
    sched.enqueue(a), sched.enqueue(b)
    assert len(sched.next_batch()) == 2  # the leased one rides for free


def test_requeue_bypasses_admission_caps():
    sched = QueryScheduler(max_pending_total=1)
    a = make_submission(1, "t", EXPRS[0], 0, SCHEMA, n_events=192)
    b = make_submission(2, "t", EXPRS[1], 0, SCHEMA, n_events=192)
    sched.enqueue(a)
    sched.requeue(b)  # fallback path: already admitted once
    assert sched.n_pending == 2
    assert sched.next_batch()[0].ticket == 2  # requeued at the FRONT


# ----------------------- property test (random workloads) --------------- #
def _check_duplicate_workload(picks):
    """The single-flight invariant pair for one random workload: every
    result bit-identical to the lease-disabled run, and total fleet-wide
    scanned events bounded by the workload's UNIQUE structure."""
    exprs = [EXPRS[p] for p in picks]
    base_finals, _, _ = baseline_results(make_store(), exprs)
    fleet = make_fleet(make_store(), 4)
    n_events = fleet.store.n_events
    tids = [fleet.submit(e, frontend=i % 4, stream=True)
            for i, e in enumerate(exprs)]
    fleet.drain()
    for t, want in zip(tids, base_finals):
        r = fleet.result(t)
        assert r.status == "SERVED"
        assert merge_lib.results_identical(r.result, want)
    unique = len(set(picks))
    s = fleet.fleet_stats()
    assert s["events_scanned"] <= unique * n_events
    fleet.close()


@pytest.mark.parametrize("seed", LEASE_SEEDS)
def test_random_duplicate_workloads_bit_identical_and_bounded(seed):
    import random
    rng = random.Random(seed)
    for _ in range(4):
        picks = [rng.randrange(len(EXPRS))
                 for _ in range(rng.randint(4, 10))]
        _check_duplicate_workload(picks)


def test_hypothesis_duplicate_workloads_bit_identical_and_bounded():
    hypothesis = pytest.importorskip("hypothesis")
    st = hypothesis.strategies

    @hypothesis.settings(max_examples=12, deadline=None)
    @hypothesis.given(st.lists(st.integers(min_value=0, max_value=2),
                               min_size=4, max_size=10))
    def run(picks):
        _check_duplicate_workload(picks)

    run()


# ------------------------- L2 persistence ------------------------------- #
def test_fleet_l2_persists_across_restart_zero_io_hits(tmp_path):
    path = tmp_path / "l2.json"
    store = make_store()
    fleet = make_fleet(store, 2, l2_path=path)
    t = fleet.submit(Q, frontend=0)
    fleet.drain()
    want = fleet.result(t).result
    assert fleet.frontends[0].service.stats.events_scanned > 0
    fleet.close()  # checkpoints the L2
    assert path.exists()

    reborn = make_fleet(make_store(), 2, l2_path=path)
    assert len(reborn.l2) > 0  # booted from the checkpoint
    t2 = reborn.submit(Q, frontend=1)
    reborn.drain()
    r = reborn.result(t2)
    assert r.status == "SERVED" and r.from_cache
    assert merge_lib.results_identical(r.result, want)
    # the whole post-restart fleet did ZERO brick I/O
    assert all(fe.service.stats.events_scanned == 0
               for fe in reborn.frontends)
    reborn.close()


def test_fleet_l2_periodic_checkpoint_during_operation(tmp_path):
    path = tmp_path / "l2.json"
    fleet = make_fleet(make_store(), 2, l2_path=path,
                       l2_checkpoint_every=1)
    fleet.submit(Q, frontend=0)
    fleet.step()
    assert path.exists()  # checkpointed by step(), before any close()
    fleet.close()


# --------------------- re-replication transfer charge ------------------- #
def test_rereplication_copies_charge_transfer_time_in_jobstats():
    from repro.core.backend import SimulatedBackend
    from repro.core.catalog import MetadataCatalog

    store = make_store()
    bid = sorted(store.bricks)[0]
    src = store.owners(bid)[0]
    dst = next(n for n in range(store.n_nodes)
               if n not in store.owners(bid))

    def run(rereplicated):
        cat = MetadataCatalog(store.n_nodes)
        be = SimulatedBackend(cat, store, adaptive_packets=False)
        jids = [be.submit(e) for e in EXPRS]
        merged, stats = be.run_batch(jids, rereplicated=rereplicated)
        return merged, stats

    free_merged, free_stats = run(None)
    paid_merged, paid_stats = run([(bid, src, dst)])

    assert free_stats.rereplication_transfer_s == 0.0
    spec = store.specs[bid]
    tm = SimulatedBackend(MetadataCatalog(store.n_nodes), store).engine.tm
    want = spec.n_events * tm.brick_bytes_per_event / tm.bandwidth_Bps
    assert paid_stats.rereplication_transfer_s == pytest.approx(want)
    # the copy delays the endpoints, so the window's makespan can only
    # grow — data movement is visible on the virtual clock
    assert paid_stats.makespan_s >= free_stats.makespan_s
    # and it never changes results
    for a, b in zip(free_merged, paid_merged):
        assert merge_lib.results_identical(a, b)


def test_policy_decision_carries_rereplications_to_backend():
    from repro.service.policy import PolicyDecision
    d = PolicyDecision(rereplicated=[(3, 0, 1)])
    kw = d.backend_kwargs()
    assert kw["rereplicated"] == [(3, 0, 1)]
