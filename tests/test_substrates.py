"""Substrate tests: checkpointing (atomic, async, restore-by-path),
brick data pipeline (determinism, failover), trainer restart, optimizer,
gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.core.catalog import MetadataCatalog
from repro.data.pipeline import BrickDataPipeline, TokenBrickStore
from repro.optim.adamw import AdamW, adamw_update, init_opt_state
from repro.optim.schedule import cosine_schedule
from repro.parallel.collectives import (compress_with_feedback,
                                        dequantize_int8, quantize_int8)


# ---------------------------- checkpoint ---------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "c": jnp.int32(7)}
    save_checkpoint(tmp_path, 3, tree)
    out, manifest = restore_checkpoint(tmp_path)
    assert manifest["step"] == 3
    np.testing.assert_array_equal(out["a"]["b"], np.arange(6).reshape(2, 3))
    assert int(out["c"]) == 7


def test_checkpoint_retention_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"x": jnp.float32(s)})
    assert latest_step(tmp_path) == 4
    steps = sorted(int(p.name[5:]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=3, async_save=True)
    mgr.save(1, {"x": jnp.arange(10)})
    mgr.wait()
    out, m = restore_checkpoint(tmp_path)
    np.testing.assert_array_equal(out["x"], np.arange(10))


def test_checkpoint_restore_with_abstract_dtype_cast(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.ones((4, 4), jnp.float32)})
    abstract = {"w": jax.ShapeDtypeStruct((4, 4), jnp.bfloat16)}
    out, _ = restore_checkpoint(tmp_path, abstract=abstract)
    assert out["w"].dtype == jnp.bfloat16


# ---------------------------- data pipeline ---------------------------- #
def _pipeline(n_nodes=4, global_batch=8):
    cat = MetadataCatalog(n_nodes)
    store = TokenBrickStore(vocab_size=100, seq_len=16, n_bricks=8,
                            seqs_per_brick=8, n_nodes=n_nodes, replication=2)
    return cat, store, BrickDataPipeline(store, cat,
                                         global_batch=global_batch)


def test_pipeline_shapes_and_range():
    cat, store, pipe = _pipeline()
    b = pipe.next_batch()
    assert b.shape == (8, 16)
    assert b.min() >= 0 and b.max() < 100


def test_bricks_replica_reads_identical():
    store = TokenBrickStore(vocab_size=100, seq_len=16, n_bricks=4,
                            seqs_per_brick=8, n_nodes=4, replication=2)
    a = store.read(2, 1, 3)
    b = store.read(2, 1, 3)  # replicas regenerate the same stream
    np.testing.assert_array_equal(a, b)


def test_pipeline_survives_node_failure():
    cat, store, pipe = _pipeline()
    b0 = pipe.next_batch()
    cat.mark_dead(0)
    pipe.sched.requeue_node(0)
    b1 = pipe.next_batch()  # must still assemble a full batch
    assert b1.shape == b0.shape


# ---------------------------- optimizer ---------------------------- #
def test_adamw_decreases_quadratic_loss():
    params = {"w": jnp.array([3.0, -2.0])}
    opt = AdamW(weight_decay=0.0, grad_clip=1e9)
    state = init_opt_state(params, opt)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, 0.1, opt)
    assert float(loss(params)) < l0 * 0.1


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                                 total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9]  # warmup rises
    assert lrs[99] < lrs[20]  # decays
    assert min(lrs) >= 0.0


# ---------------------------- gradient compression ------------------- #
def test_int8_quantization_bounded_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


def test_error_feedback_converges():
    """With error feedback, the time-average of the compressed signal
    approaches the true gradient."""
    g = jnp.full((64,), 0.013, jnp.float32)  # small, below one quant step?
    err = jnp.zeros_like(g)
    total = jnp.zeros_like(g)
    for _ in range(100):
        q, s, err = compress_with_feedback(g, err)
        total = total + dequantize_int8(q, s)
    mean = np.asarray(total) / 100
    np.testing.assert_allclose(mean, 0.013, rtol=0.02)
