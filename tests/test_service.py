"""Multi-tenant query service: shared-scan equivalence (incl. failures),
batched SPMD/Pallas paths, result cache, scheduler fairness + admission."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.brick import create_store, gather_store
from repro.core.catalog import DONE, MetadataCatalog
from repro.core.jse import (JobSubmissionEngine, spmd_query_batch_step,
                            spmd_query_step)
from repro.service import (AdmissionError, QueryScheduler, QueryService,
                           ResultCache, make_submission)

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)


def make_store(n_events=192, n_nodes=4, replication=2, seed=7):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=replication, seed=seed)


def random_exprs(rng, k):
    """Randomized expressions spanning scalars, aggregates and logic."""
    out = []
    for _ in range(k):
        a = rng.uniform(10, 80)
        b = rng.uniform(5, 25)
        c = rng.integers(1, 4)
        form = rng.integers(0, 4)
        if form == 0:
            out.append(f"e_total > {a:.3f}")
        elif form == 1:
            out.append(f"e_total > {a:.3f} && count(pt > {b:.3f}) >= {c}")
        elif form == 2:
            out.append(f"sum(pt) < {a * 10:.2f} || n_tracks >= {c}")
        else:
            out.append(f"e_t_miss > {b:.3f} && pt_lead > {a:.3f}")
    return out


def assert_results_identical(got, want):
    assert merge_lib.results_identical(got, want)


# ------------------- shared-scan equivalence (acceptance) -------------- #
@pytest.mark.parametrize("failure_script", [None, {0.5: 1}])
def test_batch_run_bit_identical_to_independent_jobs(failure_script):
    store = make_store(n_events=256)
    rng = np.random.default_rng(3)
    exprs = random_exprs(rng, 6)

    # K independent jobs, each from a pristine catalog (identical virtual
    # trajectory -> identical packet partition as the batch run)
    singles = []
    for e in exprs:
        cat = MetadataCatalog(store.n_nodes)
        jse = JobSubmissionEngine(cat, store)
        merged, _ = jse.run_job_simulated(
            jse.submit(e), failure_script=failure_script)
        singles.append(merged)

    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    jids = [jse.submit(e) for e in exprs]
    batch, stats = jse.run_job_batch_simulated(
        jids, failure_script=failure_script)

    assert stats.events_scanned >= store.n_events  # one sweep (+ requeues)
    for got, want in zip(batch, singles):
        assert_results_identical(got, want)
    for jid in jids:
        assert cat.jobs[jid].status == DONE


def test_batch_run_rejects_incompatible_jobs():
    store = make_store()
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    j0 = jse.submit("e_total > 10", calib_iters=0)
    j1 = jse.submit("e_total > 20", calib_iters=2)
    with pytest.raises(ValueError):
        jse.run_job_batch_simulated([j0, j1])


def test_batch_scan_amortizes_events_scanned():
    store = make_store(n_events=256)
    exprs = [f"e_total > {30 + i}" for i in range(8)]
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    seq = 0
    for e in exprs:
        _, st = jse.run_job_simulated(jse.submit(e))
        seq += st.events_scanned
    cat2 = MetadataCatalog(store.n_nodes)
    jse2 = JobSubmissionEngine(cat2, store)
    _, st2 = jse2.run_job_batch_simulated([jse2.submit(e) for e in exprs])
    assert seq == 8 * store.n_events
    assert st2.events_scanned == store.n_events


# ------------------- batched SPMD / Pallas paths ----------------------- #
@pytest.mark.parametrize("use_pallas", [False, True])
def test_spmd_batch_step_matches_individual_steps(use_pallas):
    store = make_store()
    batch = {k: jnp.asarray(v) for k, v in gather_store(store).items()}
    # all-canonical family so the pallas case exercises the batched kernel
    exprs = ["e_total > 40 && count(pt > 15) >= 2",
             "e_t_miss > 25 && count(pt > 8) >= 1",
             "e_total > 10 && count(pt > 20) >= 1 && sum(pt) < 400"]
    bstep = spmd_query_batch_step(exprs, SCHEMA, calib_iters=2,
                                  use_pallas=use_pallas)
    out = bstep(batch)
    assert out["hist"].shape == (len(exprs), 64)
    for i, e in enumerate(exprs):
        single = spmd_query_step(e, SCHEMA, calib_iters=2,
                                 use_pallas=use_pallas)(batch)
        assert int(out["n_selected"][i]) == int(single["n_selected"])
        np.testing.assert_allclose(float(out["sum_var"][i]),
                                   float(single["sum_var"]), rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(out["hist"][i]),
                                      np.asarray(single["hist"]))


def test_spmd_batch_step_mixed_exprs_falls_back():
    store = make_store()
    batch = {k: jnp.asarray(v) for k, v in gather_store(store).items()}
    exprs = ["e_total > 40 && count(pt > 15) >= 2",
             "sum(pt) < 300 || n_tracks >= 5"]  # second is non-canonical
    out = spmd_query_batch_step(exprs, SCHEMA, use_pallas=True)(batch)
    for i, e in enumerate(exprs):
        single = spmd_query_step(e, SCHEMA)(batch)
        assert int(out["n_selected"][i]) == int(single["n_selected"])


# ------------------- canonicalization ---------------------------------- #
def test_canonical_expr_normalizes_spelling():
    a = query_lib.canonical_expr("e_total>40&&count(pt>15)>=2")
    b = query_lib.canonical_expr("  e_total > 40.0 && "
                                 "(count((pt > 15.0)) >= 2) ")
    assert a == b
    c = query_lib.canonical_expr("e_total > 41 && count(pt > 15) >= 2")
    assert a != c


def test_validate_expr_rejects_unknown_variable():
    with pytest.raises(query_lib.QueryError):
        query_lib.validate_expr("bogus_var > 1", SCHEMA)
    with pytest.raises(query_lib.QueryError):
        query_lib.validate_expr("pt > 1", SCHEMA)  # track var outside agg
    query_lib.validate_expr("count(pt > 1) >= 1", SCHEMA)  # ok inside


# ------------------- result cache --------------------------------------- #
def test_cache_lru_eviction_and_epoch_invalidation():
    cat = MetadataCatalog(1)
    cache = ResultCache(capacity=2, catalog=cat)
    from repro.core.merge import QueryResult
    cache.put("e_total > 1", 0, cat.dataset_epoch, QueryResult(n_selected=1))
    cache.put("e_total > 2", 0, cat.dataset_epoch, QueryResult(n_selected=2))
    assert cache.get("e_total>1", 0, cat.dataset_epoch).n_selected == 1
    cache.put("e_total > 3", 0, cat.dataset_epoch, QueryResult(n_selected=3))
    # "e_total > 2" was LRU -> evicted; "e_total > 1" survives
    assert cache.get("e_total > 2", 0, cat.dataset_epoch) is None
    assert cache.get("e_total > 1", 0, cat.dataset_epoch) is not None
    assert cache.stats.evictions == 1
    # dataset bump invalidates everything cached under the old epoch
    cat.bump_dataset_version()
    assert len(cache) == 0
    assert cache.get("e_total > 1", 0, cat.dataset_epoch) is None


def test_service_cache_hit_skips_brick_scan():
    svc = QueryService(make_store())
    t1 = svc.submit("e_total > 40", tenant="a")
    svc.drain()
    scanned = svc.stats.events_scanned
    assert scanned > 0
    t2 = svc.submit(" e_total>40.0 ", tenant="b")  # near-duplicate
    tk2 = svc.result(t2)
    assert tk2.status == "SERVED" and tk2.from_cache
    assert svc.stats.events_scanned == scanned  # zero additional brick I/O
    assert_results_identical(tk2.result, svc.result(t1).result)
    # dataset bump -> next submission is a miss and rescans
    svc.catalog.bump_dataset_version()
    t3 = svc.submit("e_total > 40", tenant="c")
    svc.drain()
    assert not svc.result(t3).from_cache
    assert svc.stats.events_scanned > scanned


# ------------------- scheduler ------------------------------------------ #
def test_scheduler_round_robin_fairness():
    sched = QueryScheduler(max_batch=4)
    tick = 0
    for i in range(6):  # noisy tenant floods first
        sched.enqueue(make_submission(tick, "noisy", f"e_total > {i}", 0,
                                      SCHEMA))
        tick += 1
    for t in ("a", "b", "c"):
        sched.enqueue(make_submission(tick, t, "e_t_miss > 5", 0, SCHEMA))
        tick += 1
    window = sched.next_batch()
    assert len(window) == 4
    # every tenant represented before the noisy tenant gets depth
    assert {s.tenant for s in window} == {"noisy", "a", "b", "c"}


def test_scheduler_coalesces_by_calib_iters():
    sched = QueryScheduler(max_batch=8)
    sched.enqueue(make_submission(0, "a", "e_total > 1", 0, SCHEMA))
    sched.enqueue(make_submission(1, "a", "e_total > 2", 4, SCHEMA))
    sched.enqueue(make_submission(2, "b", "e_total > 3", 0, SCHEMA))
    w1 = sched.next_batch()
    assert [s.calib_iters for s in w1] == [0, 0]
    w2 = sched.next_batch()
    assert [s.calib_iters for s in w2] == [4]
    assert sched.next_batch() == []


def test_scheduler_admission_control():
    sched = QueryScheduler(max_pending_per_tenant=2, max_pending_total=3)
    sched.enqueue(make_submission(0, "a", "e_total > 1", 0, SCHEMA))
    sched.enqueue(make_submission(1, "a", "e_total > 2", 0, SCHEMA))
    with pytest.raises(AdmissionError):  # tenant quota
        sched.enqueue(make_submission(2, "a", "e_total > 3", 0, SCHEMA))
    sched.enqueue(make_submission(3, "b", "e_total > 4", 0, SCHEMA))
    with pytest.raises(AdmissionError):  # global cap
        sched.enqueue(make_submission(4, "c", "e_total > 5", 0, SCHEMA))
    with pytest.raises(AdmissionError):  # bad expression rejected early
        make_submission(5, "c", "nonsense_var > 1", 0, SCHEMA)


# ------------------- frontend end-to-end -------------------------------- #
def test_service_end_to_end_matches_oracle_and_dedups():
    store = make_store(n_events=160)
    svc = QueryService(store, scheduler=QueryScheduler(max_batch=16),
                       use_cache=False)
    batch = gather_store(store)
    expect = int((batch["scalars"][:, 0] > 40).sum())
    # 3 tenants x 2 copies of the same query + one distinct query
    tids = [svc.submit("e_total > 40", tenant=f"t{i % 3}") for i in range(6)]
    tids.append(svc.submit("e_t_miss > 25", tenant="t0"))
    served = svc.step()
    assert sorted(served) == sorted(tids)
    for tid in tids[:6]:
        tk = svc.result(tid)
        assert tk.status == "SERVED"
        assert tk.result.n_selected == expect
    # dedup: 7 tickets -> 2 catalog jobs in one shared-scan batch
    assert svc.stats.jobs_run == 2
    assert svc.stats.batches == 1
    jobs = [j for j in svc.catalog.jobs.values()]
    assert len({j.batch_id for j in jobs}) == 1
    assert {j.tenant for j in jobs} <= {"t0", "t1", "t2"}
    # one sweep total for all 7 tickets
    assert svc.stats.events_scanned == store.n_events


def test_service_rejected_ticket_reports_reason():
    svc = QueryService(make_store())
    tid = svc.submit("definitely_not_a_var > 3", tenant="a")
    tk = svc.result(tid)
    assert tk.status == "REJECTED"
    assert "bad expression" in tk.note
    assert svc.scheduler.n_pending == 0


def test_all_nodes_dead_mid_scan_fails_and_never_caches():
    store = make_store(n_events=256)
    svc = QueryService(store)
    tid = svc.submit("e_total > 40", tenant="a")
    # kill every node early: the scan truncates and must NOT surface DONE
    served = svc.step(failure_script={0.01: 0, 0.02: 1, 0.03: 2, 0.04: 3})
    assert served == []  # failed tickets are not reported as served
    tk = svc.result(tid)
    assert tk.status == "FAILED" and "aborted" in tk.note
    assert len(svc.cache) == 0  # a truncated partial is never cached
    # a later identical query misses the cache (no poisoned repeat)
    for n in range(store.n_nodes):
        svc.catalog.mark_alive(n)
    tid2 = svc.submit("e_total > 40", tenant="b")
    svc.drain()
    tk2 = svc.result(tid2)
    assert tk2.status == "SERVED" and not tk2.from_cache
    batch = gather_store(store)
    assert tk2.result.n_selected == int((batch["scalars"][:, 0] > 40).sum())


def test_cache_detach_removes_catalog_hook():
    cat = MetadataCatalog(1)
    cache = ResultCache(capacity=4, catalog=cat)
    from repro.core.merge import QueryResult
    cache.put("e_total > 1", 0, cat.dataset_epoch, QueryResult())
    cache.detach()
    cat.bump_dataset_version()  # no longer reaches the cache
    assert len(cache) == 1
    assert not cat._epoch_hooks


def test_service_survives_node_failure_in_shared_scan():
    store = make_store(n_events=256)
    svc = QueryService(store, use_cache=False)
    batch = gather_store(store)
    tids = [svc.submit(f"e_total > {40 + i}", tenant=f"t{i}")
            for i in range(3)]
    svc.step(failure_script={0.5: 1})
    for i, tid in enumerate(tids):
        tk = svc.result(tid)
        assert tk.status == "SERVED"
        expect = int((batch["scalars"][:, 0] > 40 + i).sum())
        assert tk.result.n_selected == expect  # no events lost
