"""Streaming partial-merge delivery: prefix snapshots bit-identical to
``tree_merge`` (incl. failure scripts + fragment plans), stream lifecycle,
backpressure, and coverage metadata."""
import numpy as np
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.brick import gather_store, create_store
from repro.core.catalog import MetadataCatalog
from repro.core.jse import JobSubmissionEngine
from repro.service import QueryService, ResultStream, StreamSnapshot
from repro.service import plan_window

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)


def make_store(n_events=256, n_nodes=4, replication=2, seed=7):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=replication, seed=seed)


def assert_results_identical(got, want):
    assert merge_lib.results_identical(got, want)


def random_partial(rng):
    n = int(rng.integers(1, 40))
    mask = rng.integers(0, 2, n)
    var = rng.uniform(0, 500, n).astype(np.float32)
    ids = rng.integers(0, 10**6, n)
    return merge_lib.from_mask(mask, var, ids)


# ------------- accumulator: the prefix-merge equivalence ---------------- #
def test_accumulator_prefix_bit_identical_to_tree_merge():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 999), n=st.integers(0, 70))
    def check(seed, n):
        rng = np.random.default_rng(seed)
        parts = [random_partial(rng) for _ in range(n)]
        acc = merge_lib.MergeAccumulator()
        assert_results_identical(acc.snapshot(), merge_lib.QueryResult())
        for k, p in enumerate(parts, 1):
            acc.add(p)
            assert_results_identical(acc.snapshot(),
                                     merge_lib.tree_merge(parts[:k]))
        assert acc.n_partials == n

    check()


def test_accumulator_prefix_identity_deterministic_sweep():
    """Hypothesis-free twin of the property above (the container may lack
    hypothesis): every prefix length 0..40 across several seeds."""
    for seed in range(6):
        rng = np.random.default_rng(seed)
        parts = [random_partial(rng) for _ in range(40)]
        acc = merge_lib.MergeAccumulator()
        for k, p in enumerate(parts, 1):
            acc.add(p)
            assert_results_identical(acc.snapshot(),
                                     merge_lib.tree_merge(parts[:k]))


def test_accumulator_snapshot_does_not_mutate():
    rng = np.random.default_rng(0)
    parts = [random_partial(rng) for _ in range(11)]
    acc = merge_lib.MergeAccumulator()
    for p in parts:
        acc.add(p)
        first = acc.snapshot()
        again = acc.snapshot()  # snapshots are pure reads
        assert_results_identical(first, again)
    assert_results_identical(acc.snapshot(), merge_lib.tree_merge(parts))


def test_accumulator_coverage_metadata():
    acc = merge_lib.MergeAccumulator(events_total=100, bricks_total=3)
    cov = acc.coverage()
    assert cov.fraction == 0.0 and not cov.complete and cov.packets == 0
    rng = np.random.default_rng(1)
    seen = 0
    for i in range(4):
        p = random_partial(rng)
        seen += p.n_processed
        acc.add(p, brick_id=i % 3)
    acc.note_failure()
    cov = acc.coverage()
    assert cov.events_scanned == seen
    assert cov.bricks_seen == (0, 1, 2) and cov.bricks_total == 3
    assert cov.packets == 4 and cov.failures == 1
    assert cov.complete == (seen >= 100)
    # unknown totals -> fraction is None, never "complete"
    assert merge_lib.MergeAccumulator().coverage().fraction is None
    assert not merge_lib.MergeAccumulator().coverage().complete


# ------------- JSE hook: prefix snapshots under plans + failures -------- #
@pytest.mark.parametrize("failure_script", [None, {0.5: 1}])
def test_streamed_prefixes_merge_to_tree_merge_with_fragment_plan(
        failure_script):
    """The acceptance property: every streamed prefix snapshot equals
    ``tree_merge`` of the partials so far, and the last one equals the
    batch result — with a materializing FragmentPlan and node failures."""
    store = make_store(n_events=256)
    exprs = ["e_total > 40 && count(pt > 15) >= 2",
             "e_total > 30 && count(pt > 15) >= 2",
             "e_t_miss > 25 && sum(pt) < 400"]
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    jids = [jse.submit(e) for e in exprs]
    plan = plan_window(exprs, materialize=True)
    n_targets = len(plan.targets())
    assert n_targets > len(exprs)  # shared fragments really materialized

    accs = [merge_lib.MergeAccumulator(events_total=store.n_events)
            for _ in range(n_targets)]
    columns = [[] for _ in range(n_targets)]
    seqs = []

    def on_partial(pp):
        seqs.append(pp.seq)
        assert len(pp.partials) == n_targets
        for col in range(n_targets):
            columns[col].append(pp.partials[col])
            accs[col].add(pp.partials[col], brick_id=pp.brick_id)
            assert_results_identical(
                accs[col].snapshot(),
                merge_lib.tree_merge(columns[col]))

    merged, stats = jse.run_job_batch_simulated(
        jids, plan=plan, failure_script=failure_script,
        on_partial=on_partial)
    assert seqs == list(range(len(seqs)))  # emitted in merge order
    # final prefix == batch merge for every root column...
    for col in range(len(exprs)):
        assert_results_identical(accs[col].snapshot(), merged[col])
        assert accs[col].coverage().complete
    # ...and for every materialized shared-fragment column
    for off, key in enumerate(plan.materialize_keys()):
        assert_results_identical(accs[len(exprs) + off].snapshot(),
                                 stats.fragment_results[key])


# ------------- service end-to-end -------------------------------------- #
@pytest.mark.parametrize("failure_script", [None, {0.5: 1}])
def test_service_streamed_final_bit_identical_to_singles(failure_script):
    store = make_store(n_events=256)
    exprs = ["e_total > 40 && count(pt > 15) >= 2",
             "e_total > 30 && count(pt > 15) >= 2",
             "e_t_miss > 25"]
    svc = QueryService(store, use_cache=False)
    tids = [svc.submit(e, tenant=f"t{i}", stream=True)
            for i, e in enumerate(exprs)]
    svc.step(failure_script=failure_script)
    for e, tid in zip(exprs, tids):
        stream = svc.stream(tid)
        assert stream.done and svc.result(tid).streamed
        snaps = list(stream)
        assert snaps[-1].final and snaps[-1].result is svc.result(tid).result
        # coverage is monotone and times are ordered
        scanned = [s.coverage.events_scanned for s in snaps]
        assert scanned == sorted(scanned)
        times = [s.t_virtual for s in snaps]
        assert times == sorted(times)
        assert times[0] < times[-1]  # first partial strictly before final
        cat = MetadataCatalog(store.n_nodes)
        jse = JobSubmissionEngine(cat, store)
        want, _ = jse.run_job_simulated(jse.submit(e),
                                        failure_script=failure_script)
        assert_results_identical(snaps[-1].result, want)


def test_service_dedup_fans_stream_out_to_all_tickets():
    store = make_store(n_events=192)
    svc = QueryService(store, use_cache=False)
    a = svc.submit("e_total > 40", tenant="a", stream=True)
    b = svc.submit(" e_total>40.0 ", tenant="b", stream=True)  # same canonical
    c = svc.submit("e_total > 40", tenant="c")  # unstreamed rider
    svc.step()
    sa, sb = svc.stream(a), svc.stream(b)
    assert sa.done and sb.done
    assert sa.latest().result is sb.latest().result
    assert sa.published == sb.published > 1
    with pytest.raises(KeyError):
        svc.stream(c)  # only stream=True tickets have streams


def test_stream_aborts_when_scan_truncates_and_publishes_no_final():
    store = make_store(n_events=256)
    svc = QueryService(store)
    tid = svc.submit("e_total > 40", tenant="a", stream=True)
    svc.step(failure_script={0.01: 0, 0.02: 1, 0.03: 2, 0.04: 3})
    stream = svc.stream(tid)
    assert stream.state == "ABORTED" and "aborted" in stream.note
    assert not stream.done
    # whatever partial prefixes got out are readable but none is final
    for snap in stream:
        assert not snap.final and not snap.coverage.complete


def test_cache_hit_streams_single_final_snapshot():
    store = make_store(n_events=192)
    svc = QueryService(store)
    t1 = svc.submit("e_total > 40", tenant="a")
    svc.drain()
    t2 = svc.submit("e_total > 40", tenant="b", stream=True)
    stream = svc.stream(t2)
    assert svc.result(t2).from_cache and stream.done
    assert stream.published == 1
    snap = stream.latest()
    assert snap.final and snap.coverage.complete
    assert_results_identical(snap.result, svc.result(t1).result)


def test_rejected_submission_aborts_stream():
    svc = QueryService(make_store())
    tid = svc.submit("definitely_not_a_var > 3", tenant="a", stream=True)
    stream = svc.stream(tid)
    assert stream.state == "ABORTED" and "bad expression" in stream.note
    assert stream.latest() is None


def test_release_stream_drops_buffers_but_keeps_ticket():
    store = make_store(n_events=192)
    svc = QueryService(store, use_cache=False)
    tid = svc.submit("e_total > 40", tenant="a", stream=True)
    svc.step()
    want = svc.stream(tid).latest().result
    svc.release_stream(tid)
    with pytest.raises(KeyError):
        svc.stream(tid)
    svc.release_stream(tid)  # idempotent
    assert svc.result(tid).result is want  # ticket result survives


# ------------- stream mechanics ----------------------------------------- #
def _snap(seq, final=False):
    return StreamSnapshot(seq=seq, result=merge_lib.QueryResult(),
                          coverage=merge_lib.Coverage(), t_virtual=float(seq),
                          final=final)


def test_stream_backpressure_conflates_oldest():
    rs = ResultStream(0, capacity=3)
    for i in range(7):
        rs.publish(_snap(i))
    assert len(rs) == 3 and rs.dropped == 4 and rs.published == 7
    assert [s.seq for s in rs] == [4, 5, 6]  # oldest conflated away
    rs.finish(_snap(7, final=True))
    assert rs.done and rs.latest().final
    assert rs.poll().seq == 7  # final survives in the (empty) buffer
    assert rs.poll() is None
    # publishing after close is a no-op
    rs.publish(_snap(8))
    assert rs.published == 8 and len(rs) == 0


def test_stream_subscribe_pushes_every_publish():
    rs = ResultStream(0, capacity=2)  # tighter than the publish count
    seen = []
    rs.subscribe(lambda s: seen.append(s.seq))
    for i in range(5):
        rs.publish(_snap(i))
    assert seen == [0, 1, 2, 3, 4]  # push sees all, buffer conflates
    assert len(rs) == 2


def test_stream_capacity_validation():
    with pytest.raises(ValueError):
        ResultStream(0, capacity=0)


# ------------- non-streamed path unchanged ------------------------------ #
def test_unstreamed_service_has_no_streams_and_identical_results():
    store = make_store(n_events=192)
    svc = QueryService(store, use_cache=False)
    tid = svc.submit("e_total > 40", tenant="a")
    svc.step()
    assert svc.streams == {}
    batch = gather_store(store)
    assert svc.result(tid).result.n_selected == int(
        (batch["scalars"][:, 0] > 40).sum())
    assert not svc.result(tid).streamed
