"""Satellite coverage: merge algebra (associativity/commutativity incl.
selected_ids bounding), multi-node re-replication, and elastic
join/leave edge cases."""
import numpy as np

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.brick import create_store
from repro.core.catalog import MetadataCatalog
from repro.core.elastic import ElasticManager
from repro.core.replication import rereplication_plan

SCHEMA = ev.EventSchema.from_config(reduced())


def _parts(seed, n_parts=5, n=40):
    rng = np.random.default_rng(seed)
    parts = []
    for i in range(n_parts):
        mask = rng.integers(0, 2, n)
        var = rng.uniform(0, 500, n).astype(np.float32)
        ids = np.arange(i * n, (i + 1) * n)
        parts.append(merge_lib.from_mask(mask, var, ids))
    return parts


def _agg_equal(a, b):
    assert a.n_selected == b.n_selected
    assert a.n_processed == b.n_processed
    assert np.isclose(a.sum_var, b.sum_var, rtol=1e-6)
    np.testing.assert_array_equal(a.hist, b.hist)


# ------------------------- merge2 algebra ------------------------------ #
def test_merge2_commutative_on_aggregates():
    a, b = _parts(0, n_parts=2)
    _agg_equal(merge_lib.merge2(a, b), merge_lib.merge2(b, a))


def test_merge2_associative_on_aggregates():
    a, b, c = _parts(1, n_parts=3)
    left = merge_lib.merge2(merge_lib.merge2(a, b), c)
    right = merge_lib.merge2(a, merge_lib.merge2(b, c))
    _agg_equal(left, right)
    # selected_ids: same ID SET prefix regardless of association, and
    # always bounded
    assert len(left.selected_ids) == len(right.selected_ids) <= \
        merge_lib.MAX_IDS
    np.testing.assert_array_equal(left.selected_ids, right.selected_ids)


def test_selected_ids_bounded_under_merge():
    rng = np.random.default_rng(2)
    parts = []
    for i in range(4):
        n = 200  # each part alone selects > MAX_IDS events
        mask = np.ones(n, np.int64)
        var = rng.uniform(0, 500, n).astype(np.float32)
        parts.append(merge_lib.from_mask(mask, var,
                                         np.arange(i * n, (i + 1) * n)))
        assert len(parts[-1].selected_ids) == merge_lib.MAX_IDS
    merged = merge_lib.tree_merge(parts)
    assert len(merged.selected_ids) == merge_lib.MAX_IDS
    # bounded sample keeps the earliest packet's ids (deterministic prefix)
    np.testing.assert_array_equal(merged.selected_ids,
                                  parts[0].selected_ids)


def test_tree_merge_equals_linear_fold_and_identity():
    parts = _parts(3, n_parts=7)
    lin = parts[0]
    for p in parts[1:]:
        lin = merge_lib.merge2(lin, p)
    _agg_equal(merge_lib.tree_merge(parts), lin)
    # empty QueryResult is the merge identity
    ident = merge_lib.merge2(parts[0], merge_lib.QueryResult())
    _agg_equal(ident, parts[0])
    np.testing.assert_array_equal(ident.selected_ids,
                                  parts[0].selected_ids)


def test_merge_batch_is_per_query_tree_merge():
    cols = [_parts(s, n_parts=4) for s in (4, 5, 6)]  # 3 queries
    packets = [[cols[q][i] for q in range(3)] for i in range(4)]
    merged = merge_lib.merge_batch(packets)
    assert len(merged) == 3
    for q in range(3):
        _agg_equal(merged[q], merge_lib.tree_merge(cols[q]))


# ------------------------- re-replication ------------------------------ #
def test_rereplication_restores_factor_after_multi_node_failure():
    n_nodes, repl = 8, 3
    store = create_store(SCHEMA, n_events=256, n_nodes=n_nodes,
                         events_per_brick=16, replication=repl, seed=9)
    dead = {1, 4}  # simultaneous two-node failure
    plan = rereplication_plan(store.specs, dead, n_nodes)
    for bid, src, dst in plan:
        assert src not in dead and dst not in dead
        spec = store.specs[bid]
        assert dst not in (spec.node, *spec.replicas)  # no double placement
        spec.replicas = spec.replicas + (dst,)
    for bid, spec in store.specs.items():
        alive_owners = {n for n in store.owners(bid) if n not in dead}
        assert len(alive_owners) >= min(repl, n_nodes - len(dead))


def test_rereplication_plan_all_replica_owners_dead():
    # every owner (primary + replicas) of some bricks is dead: those
    # bricks are unrecoverable and must NOT appear in the copy plan —
    # there is no surviving source to copy from
    n_nodes = 4
    store = create_store(SCHEMA, n_events=128, n_nodes=n_nodes,
                         events_per_brick=16, replication=2, seed=12)
    doomed = next(bid for bid, spec in sorted(store.specs.items()))
    owners = set(store.owners(doomed))
    plan = rereplication_plan(store.specs, owners, n_nodes)
    assert all(bid != doomed for bid, _, _ in plan)
    for bid, src, dst in plan:
        assert src not in owners and dst not in owners
    # degenerate extreme: the whole grid dead -> empty plan, no crash
    assert rereplication_plan(store.specs, set(range(n_nodes)),
                              n_nodes) == []


def test_elastic_node_join_rebalances_toward_target():
    n_nodes = 4
    store = create_store(SCHEMA, n_events=256, n_nodes=n_nodes,
                         events_per_brick=16, replication=2, seed=13)
    cat = MetadataCatalog(n_nodes)
    mgr = ElasticManager(cat, store)
    # node 3 leaves: its bricks fail over to replicas
    leave = mgr.node_leave(3)
    assert leave.reassign_primary and not leave.lost_bricks
    assert all(spec.node != 3 for spec in store.specs.values())
    mgr.apply_copies(leave)
    # node 3 rejoins: the most-loaded donors shed bricks to it until it
    # holds ~total/alive
    join = mgr.node_join(3)
    assert 3 in cat.alive_nodes()
    assert join.reassign_primary  # bricks actually moved to the joiner
    target = len(store.specs) // len(cat.alive_nodes())
    have = sum(1 for spec in store.specs.values() if spec.node == 3)
    assert have >= min(1, target)
    for bid, donor, dst in join.reassign_primary:
        assert dst == 3 and donor != 3
        assert store.specs[bid].node == 3
    # no node ends up below a fair floor because of the rebalance
    loads = {}
    for spec in store.specs.values():
        loads[spec.node] = loads.get(spec.node, 0) + 1
    assert max(loads.values()) - min(loads.get(n, 0)
                                     for n in cat.alive_nodes()) <= target + 1


def test_rereplication_plan_spreads_copy_load():
    n_nodes = 10
    store = create_store(SCHEMA, n_events=320, n_nodes=n_nodes,
                         events_per_brick=16, replication=2, seed=10)
    # ring stride is 5, so {0, 4} never kills a full owner set
    dead = {0, 4}
    plan = rereplication_plan(store.specs, dead, n_nodes)
    assert plan, "two dead nodes must require copies"
    dsts = [dst for _, _, dst in plan]
    # round-robin destination choice: no single node absorbs everything
    counts = {d: dsts.count(d) for d in set(dsts)}
    assert max(counts.values()) <= len(plan) // 2 + 1
