"""Unified execution backends: contract equivalence (simulated vs SPMD
chunked streaming scan), prefix-merge bit-identity, Pallas epilogue
fusion of fragment-plan targets, SPMD telemetry feeding cost-model
calibration, window-cost-bounded dispatch, L2 persistence, and adaptive
gossip fanout."""
import numpy as np
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.backend import (ChunkController, SimulatedBackend,
                                SpmdBackend, make_backend)
from repro.core.brick import create_store
from repro.core.catalog import DONE, MetadataCatalog
from repro.fabric import SharedCacheTier, adaptive_fanout, rounds_bound
from repro.service import (QueryScheduler, QueryService, fit_cost_weights,
                           plan_window)

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)

POOL = ["e_total > 40 && count(pt > 15) >= 2",
        "e_total > 30 && count(pt > 15) >= 2",
        "e_t_miss > 25 && count(pt > 15) >= 2",
        "pt_lead > 60 || n_tracks >= 8",
        "e_total > 55 && sum(pt) < 400",
        "e_total + 2 * e_t_miss > 120"]


def make_store(n_events=192, n_nodes=4, seed=7):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=2, seed=seed)


def run_window(backend, store, exprs, *, calib=0, ramp=None):
    plan = plan_window(exprs)
    jids = [backend.catalog.submit(e, calib, tuple(sorted(store.bricks)))
            for e in exprs]
    partials = []
    merged, stats = backend.run_batch(jids, plan=plan,
                                      on_partial=partials.append,
                                      packet_ramp=ramp)
    return merged, stats, partials


def matched_backends(store, chunk=16):
    """A (sim, spmd) pair with IDENTICAL packetization: fixed sim packets
    of ``chunk`` events, spmd chunks of ``chunk`` events."""
    sim = SimulatedBackend(MetadataCatalog(store.n_nodes), store,
                           adaptive_packets=False)
    sim.engine.adaptive_packets = False
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=chunk)
    # the sim's fixed packet size is the scheduler base (64); pin it to
    # the spmd chunk so decompositions line up exactly
    return sim, spmd


def assert_window_equivalent(sim_out, spmd_out):
    (m1, s1, p1), (m2, s2, p2) = sim_out, spmd_out
    assert s1.packets == s2.packets == len(p1) == len(p2)
    for a, b in zip(m1, m2):
        assert merge_lib.results_identical(a, b)
    for pa, pb in zip(p1, p2):
        assert (pa.seq, pa.brick_id, pa.start, pa.size) == \
               (pb.seq, pb.brick_id, pb.start, pb.size)
        assert all(merge_lib.results_identical(a, b)
                   for a, b in zip(pa.partials, pb.partials))
    assert set(s1.fragment_results) == set(s2.fragment_results)
    for key, res in s1.fragment_results.items():
        assert merge_lib.results_identical(res, s2.fragment_results[key])


# ----------------------- contract equivalence --------------------------- #
def test_backends_bit_identical_on_matched_packetization():
    store = make_store()
    sim, spmd = matched_backends(store, chunk=64)
    out1 = run_window(sim, store, POOL, calib=2)
    out2 = run_window(spmd, store, POOL, calib=2)
    assert_window_equivalent(out1, out2)
    # both catalogues converged to DONE with the same result summaries
    for cat in (sim.catalog, spmd.catalog):
        assert all(r.status == DONE for r in cat.jobs.values())


def test_backend_equivalence_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    store = make_store(n_events=96)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 99),
           calib=st.sampled_from([0, 2]),
           k=st.integers(1, 4))
    def check(seed, calib, k):
        rng = np.random.default_rng(seed)
        exprs = [POOL[i] for i in rng.choice(len(POOL), size=k,
                                             replace=False)]
        sim, spmd = matched_backends(store, chunk=64)
        assert_window_equivalent(
            run_window(sim, store, exprs, calib=calib),
            run_window(spmd, store, exprs, calib=calib))

    check()


def test_spmd_prefix_snapshots_bit_identical_to_tree_merge():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=16)
    merged, stats, partials = run_window(spmd, store, POOL[:3])
    assert stats.packets == len(partials) > 1
    for col in range(len(POOL[:3])):
        acc = merge_lib.MergeAccumulator()
        for k, pp in enumerate(partials, 1):
            acc.add(pp.partials[col], brick_id=pp.brick_id)
            want = merge_lib.tree_merge(
                [p.partials[col] for p in partials[:k]])
            assert merge_lib.results_identical(acc.snapshot(), want)
        assert merge_lib.results_identical(acc.snapshot(), merged[col])
    # merge order is deterministic: brick id ascending, offset ascending
    order = [(p.brick_id, p.start) for p in partials]
    assert order == sorted(order)
    # wall-clock availability stamps are non-decreasing
    times = [p.t_virtual for p in partials]
    assert times == sorted(times)


def test_spmd_packet_ramp_caps_early_chunks():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=16)
    _, _, partials = run_window(spmd, store, ["e_total > 40"], ramp=4)
    assert partials[0].size == 4
    assert partials[1].size == 8
    assert max(p.size for p in partials) <= 16


def test_spmd_rejects_failure_script():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store)
    assert not spmd.supports_failure_injection
    jid = spmd.catalog.submit("e_total > 40", 0,
                              tuple(sorted(store.bricks)))
    with pytest.raises(ValueError, match="simulated-grid"):
        spmd.run_batch([jid], failure_script={0.5: 1})


def test_service_rejects_failure_script_before_dequeue():
    store = make_store()
    svc = QueryService(store, backend="spmd")
    tid = svc.submit("e_total > 40", stream=True)
    with pytest.raises(ValueError, match="failure"):
        svc.step(failure_script={1.0: 2})
    # nothing was mutated: the window is still queued, the ticket
    # pending, the stream open — the query runs fine afterwards
    assert svc.scheduler.n_pending == 1
    assert svc.result(tid).status == "QUEUED"
    assert not svc.stream(tid).closed
    svc.step()
    assert svc.result(tid).status == "SERVED"
    assert svc.stream(tid).done
    svc.close()


def test_service_rejects_simulation_knobs_on_spmd_backend():
    from repro.core.jse import TimeModel
    store = make_store()
    with pytest.raises(ValueError, match="simulation knobs"):
        QueryService(store, backend="spmd", time_model=TimeModel())
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store)
    with pytest.raises(ValueError, match="pre-built instance"):
        QueryService(store, backend=spmd, node_speed={0: 0.5})


def test_make_backend_factory():
    store = make_store()
    cat = MetadataCatalog(store.n_nodes)
    assert isinstance(make_backend("sim", cat, store), SimulatedBackend)
    assert isinstance(make_backend("spmd", cat, store), SpmdBackend)
    with pytest.raises(ValueError, match="unknown backend"):
        make_backend("tpu", cat, store)


# ----------------------- Pallas epilogue fusion ------------------------- #
def test_match_epilogue_relaxed_family():
    from repro.kernels.event_filter import ops as ef_ops
    full = ef_ops.match_epilogue(
        "e_total > 40 && count(pt > 15) >= 2 && sum(pt) < 400", SCHEMA)
    assert full["scalar_thresh"] == 40 and full["min_count"] == 2 \
        and full["sum_cap"] == 400
    bare_count = ef_ops.match_epilogue("count(pt > 15) >= 2", SCHEMA)
    assert bare_count is not None
    assert bare_count["scalar_thresh"] == float("-inf")
    assert bare_count["min_count"] == 2
    lone_scalar = ef_ops.match_epilogue("e_t_miss > 25", SCHEMA)
    assert lone_scalar is not None and lone_scalar["min_count"] == 0
    # outside the conjunctive family
    assert ef_ops.match_epilogue("pt_lead > 60 || n_tracks >= 8",
                                 SCHEMA) is None
    assert ef_ops.match_epilogue("e_total + 2 * e_t_miss > 120",
                                 SCHEMA) is None
    assert ef_ops.match_epilogue("sum(pt) < 0", SCHEMA) is None  # aliases
    assert ef_ops.match_epilogue("nope > 3", SCHEMA) is None


def test_spmd_pallas_fusion_matches_jnp_plan():
    store = make_store(n_events=96)
    exprs = POOL[:3]  # shared count fragment -> materialized target
    plan = plan_window(exprs)
    assert plan.materialize, "expected a materialized shared fragment"
    ref = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                      chunk_events=32)
    fused = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        chunk_events=32, use_pallas=True)
    # the fusion hook actually engages for this window (every target —
    # roots AND the materialized boolean fragment — is in-family)
    assert fused._fuse_plan(plan) is not None
    out_ref = run_window(ref, store, exprs, calib=2)
    out_fused = run_window(fused, store, exprs, calib=2)
    assert_window_equivalent(out_ref, out_fused)


def test_spmd_pallas_falls_back_on_out_of_family_target():
    store = make_store(n_events=64)
    fused = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        use_pallas=True)
    plan = plan_window(["pt_lead > 60 || n_tracks >= 8"])
    assert fused._fuse_plan(plan) is None
    merged, _, _ = run_window(fused, store,
                              ["pt_lead > 60 || n_tracks >= 8"])
    sim = SimulatedBackend(MetadataCatalog(store.n_nodes), store,
                           adaptive_packets=False)
    want, _, _ = run_window(sim, store, ["pt_lead > 60 || n_tracks >= 8"])
    assert merge_lib.results_identical(merged[0], want[0])


# ----------------------- mixed-window splitting ------------------------- #
MIXED = [POOL[0], POOL[3], POOL[4], POOL[5]]  # 2 in-family, 2 out


def test_spmd_mixed_window_splits_kernel_and_jnp():
    """A window with BOTH in-family and out-of-family targets no longer
    falls back wholesale to jnp: the in-family targets run as a kernel
    sub-batch (kernel_events > 0) and everything stays bit-identical to
    the pure-jnp scan — finals AND per-packet partials."""
    store = make_store(n_events=96)
    plain = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        chunk_events=32)
    fused = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        chunk_events=32, use_pallas=True)
    plan = plan_window(MIXED)
    split = fused._split_plan(plan)
    assert split.any_kernel and not split.full_kernel
    assert fused._fuse_plan(plan) is None  # not FULLY fused...
    out_plain = run_window(plain, store, MIXED, calib=2)
    out_fused = run_window(fused, store, MIXED, calib=2)
    assert_window_equivalent(out_plain, out_fused)
    # ...yet the kernel sub-batch actually ran (the acceptance signal)
    assert out_fused[1].kernel_events == store.n_events
    assert out_plain[1].kernel_events == 0


def test_mixed_split_bit_identity_property():
    """Property: for ANY subset of the pool (some targets epilogue-
    eligible, some not) and any chunking, the kernel/jnp split returns
    bit-identical finals and prefix snapshots vs the pure-jnp path."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    store = make_store(n_events=96)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 999), k=st.integers(1, 5),
           chunk=st.sampled_from([16, 48, 96]), calib=st.sampled_from([0, 2]))
    def check(seed, k, chunk, calib):
        rng = np.random.default_rng(seed)
        exprs = [POOL[i] for i in rng.choice(len(POOL), size=k,
                                             replace=False)]
        plain = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                            chunk_events=chunk)
        fused = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                            chunk_events=chunk, use_pallas=True)
        out_plain = run_window(plain, store, exprs, calib=calib)
        out_fused = run_window(fused, store, exprs, calib=calib)
        assert_window_equivalent(out_plain, out_fused)
        split = fused._split_plan(plan_window(exprs))
        assert out_fused[1].kernel_events == (
            store.n_events if split.any_kernel else 0)
        # prefix snapshots: accumulate both partial streams in lockstep
        for col in range(k):
            acc_p, acc_f = (merge_lib.MergeAccumulator(),
                            merge_lib.MergeAccumulator())
            for pp, pf in zip(out_plain[2], out_fused[2]):
                acc_p.add(pp.partials[col], brick_id=pp.brick_id)
                acc_f.add(pf.partials[col], brick_id=pf.brick_id)
                assert merge_lib.results_identical(acc_p.snapshot(),
                                                   acc_f.snapshot())

    check()


# ----------------------- adaptive chunk sizing -------------------------- #
def test_chunk_controller_converges_to_target():
    ctl = ChunkController(initial=64, min_chunk=8, max_chunk=4096,
                          target_s=0.01, alpha=0.5, hysteresis=0.0)
    assert ctl.chunk() == 64
    for _ in range(32):
        ctl.observe(events=64, wall_s=0.001)  # steady 64k events/s
    # rate EWMA converged; proposal = rate * target_s = 640
    assert abs(ctl.scan_rate - 64_000) / 64_000 < 1e-6
    assert ctl.chunk() == 640
    # clamping: a crawling scan floors at min_chunk
    for _ in range(64):
        ctl.observe(events=8, wall_s=10.0)
    assert ctl.chunk() == 8


def test_chunk_controller_hysteresis_dead_band():
    ctl = ChunkController(initial=100, target_s=1.0, alpha=1.0,
                          hysteresis=0.25)
    ctl.observe(events=100, wall_s=1.0)   # rate 100 -> proposal 100
    assert ctl.chunk() == 100
    ctl.observe(events=110, wall_s=1.0)   # +10% < 25% dead-band: held
    assert ctl.chunk() == 100
    ctl.observe(events=200, wall_s=1.0)   # +100%: moves
    assert ctl.chunk() == 200
    # ignores degenerate observations
    ctl.observe(events=0, wall_s=1.0)
    ctl.observe(events=10, wall_s=0.0)
    assert ctl.chunk() == 200


def test_chunk_controller_validation():
    with pytest.raises(ValueError):
        ChunkController(alpha=0.0)
    with pytest.raises(ValueError):
        ChunkController(min_chunk=0)
    with pytest.raises(ValueError):
        ChunkController(min_chunk=64, max_chunk=8)
    with pytest.raises(ValueError):
        ChunkController(target_s=0.0)
    with pytest.raises(ValueError):
        ChunkController(hysteresis=-0.1)


class TickClock:
    """Deterministic injectable clock: advances a fixed dt per call, so
    measured 'walls' — and everything derived from them, like adaptive
    chunk boundaries — replay identically run over run."""

    def __init__(self, dt=0.003):
        self.t, self.dt = 0.0, dt

    def __call__(self):
        self.t += self.dt
        return self.t


def test_spmd_adaptive_chunks_resize_and_stay_correct():
    """Adaptive chunks change packetization, not answers: with the same
    injected clock, the jnp and kernel-split scans pick the SAME chunk
    boundaries and stay bit-identical; against a sim reference the exact
    counts match and sum_var agrees to float regrouping."""
    store = make_store()

    def adaptive(**kw):
        return SpmdBackend(MetadataCatalog(store.n_nodes), store,
                           chunk_events=8, adaptive_chunks=True,
                           chunk_target_s=0.05, clock=TickClock(), **kw)

    out_jnp = run_window(adaptive(), store, POOL[:3], calib=2)
    out_ker = run_window(adaptive(use_pallas=True), store, POOL[:3],
                         calib=2)
    assert_window_equivalent(out_jnp, out_ker)
    partials = out_jnp[2]
    # the controller actually moved chunk sizes off the initial value
    assert len({p.size for p in partials}) > 1
    # exact-count agreement with the simulated reference (sum_var may
    # regroup: adaptive chunk boundaries differ from sim packets)
    sim = SimulatedBackend(MetadataCatalog(store.n_nodes), store,
                           adaptive_packets=False)
    want, _, _ = run_window(sim, store, POOL[:3], calib=2)
    for a, b in zip(want, out_jnp[0]):
        assert a.n_selected == b.n_selected
        assert a.n_processed == b.n_processed
        assert np.allclose(a.sum_var, b.sum_var)
        assert np.array_equal(a.hist, b.hist)


def test_spmd_adaptive_chunks_deterministic_flight_log(tmp_path):
    """Adaptive chunk sizing must not break the flight recorder's
    byte-identical replayability: with the backend clock injected, two
    identical runs produce identical chunk boundaries, identical stream
    snapshots, and byte-identical flight logs."""
    from repro.fabric.fleet import Fleet

    def one_run(path):
        store = make_store()
        fleet = Fleet(store, 2, backend="spmd", obs=True, flight=True,
                      backend_kwargs=dict(chunk_events=8,
                                          adaptive_chunks=True,
                                          chunk_target_s=0.05,
                                          clock=TickClock()))
        for i, e in enumerate(POOL[:3]):
            fleet.submit(e, tenant=f"t{i}", stream=True)
        fleet.drain()
        fleet.save_flight(path)
        fleet.close()
        return path.read_bytes()

    a = one_run(tmp_path / "a.jsonl")
    b = one_run(tmp_path / "b.jsonl")
    assert a == b


# ----------------------- mesh-sharded chunks ---------------------------- #
def test_spmd_mesh_lockstep_emulation_bit_identical():
    """mesh_devices > jax devices: the mesh is emulated with lockstep
    critical-path accounting — results and partials stay bit-identical
    to the single-device scan, stamps ride the lockstep clock."""
    store = make_store(n_events=96)
    base = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=16, use_pallas=True)
    mesh = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=16, use_pallas=True, mesh_devices=4)
    assert not mesh._mesh_is_real()
    out_base = run_window(base, store, POOL, calib=2)
    out_mesh = run_window(mesh, store, POOL, calib=2)
    assert_window_equivalent(out_base, out_mesh)
    stats, partials = out_mesh[1], out_mesh[2]
    # lockstep makespan is the sum of per-group maxima: no larger than
    # the serial sum of walls, no smaller than the largest single wall
    serial = sum(t.wall_s for t in stats.packet_telemetry)
    assert max(t.wall_s for t in stats.packet_telemetry) \
        <= stats.makespan_s <= serial + 1e-9
    times = [p.t_virtual for p in partials]
    assert times == sorted(times)


def test_spmd_real_mesh_shard_map_bit_identical():
    """With enough physical devices, mesh groups execute as ONE
    shard_map call over stacked padded sub-chunks — and partials stay
    bit-identical to the sequential scan (subprocess: jax pins its
    device count at first init)."""
    from tests.test_multidevice import run_with_devices
    run_with_devices("""
        from tests.test_backend import (make_store, run_window, POOL,
                                        assert_window_equivalent)
        from repro.core.backend import SpmdBackend
        from repro.core.catalog import MetadataCatalog
        assert len(jax.devices()) == 2
        store = make_store(n_events=96)
        base = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                           chunk_events=16, use_pallas=True)
        mesh = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                           chunk_events=16, use_pallas=True,
                           mesh_devices=2)
        out_base = run_window(base, store, POOL, calib=2)
        out_mesh = run_window(mesh, store, POOL, calib=2)
        assert mesh._mesh_is_real()
        assert_window_equivalent(out_base, out_mesh)
        assert out_mesh[1].kernel_events == store.n_events
        print("OK")
    """, n=2)


def test_spmd_double_buffer_preserves_order_and_results():
    store = make_store(n_events=96)
    on = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                     chunk_events=16, use_pallas=True, double_buffer=True)
    off = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                      chunk_events=16, use_pallas=True,
                      double_buffer=False)
    assert_window_equivalent(run_window(on, store, MIXED, calib=2),
                             run_window(off, store, MIXED, calib=2))


def test_spmd_autotune_uses_cached_winner():
    from repro.kernels.event_filter import tune as ef_tune
    ef_tune.clear_cache()
    store = make_store(n_events=96)
    tuned = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        chunk_events=32, use_pallas=True, autotune=True)
    plain = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        chunk_events=32, use_pallas=True)
    out_tuned = run_window(tuned, store, POOL[:3], calib=2)
    out_plain = run_window(plain, store, POOL[:3], calib=2)
    assert_window_equivalent(out_plain, out_tuned)
    assert tuned.last_autotune is not None
    assert tuned.last_autotune.speedup_vs_default >= 1.0
    assert len(ef_tune.cached_shapes()) == 1
    # a second window of the same shape class pays no new sweep
    run_window(tuned, store, POOL[:3], calib=2)
    assert len(ef_tune.cached_shapes()) == 1


def test_service_backend_kwargs_thread_through():
    store = make_store()
    svc = QueryService(store, backend="spmd",
                       backend_kwargs=dict(use_pallas=True,
                                           chunk_events=24))
    assert svc.backend.use_pallas and svc.backend.chunk_events == 24
    tid = svc.submit(POOL[0])
    svc.step()
    assert svc.result(tid).status == "SERVED"
    svc.close()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store)
    with pytest.raises(ValueError, match="pre-built instance"):
        QueryService(store, backend=spmd,
                     backend_kwargs=dict(chunk_events=8))


# ----------------------- service integration ---------------------------- #
def test_service_backend_agnostic_end_to_end():
    store = make_store()
    results = {}
    for kind in ("sim", "spmd"):
        svc = QueryService(store, backend=kind, use_cache=True)
        tid = svc.submit(POOL[0], stream=True)
        tid2 = svc.submit(POOL[3])
        svc.drain()
        t = svc.result(tid)
        assert t.status == "SERVED"
        stream = svc.stream(tid)
        assert stream.done and stream.latest().final
        assert merge_lib.results_identical(stream.latest().result,
                                           t.result)
        assert stream.latest().coverage.complete
        # repeat submission is a zero-I/O cache hit on either backend
        tid3 = svc.submit(POOL[0])
        assert svc.result(tid3).from_cache
        results[kind] = (t.result, svc.result(tid2).result)
        svc.close()
    for a, b in zip(results["sim"], results["spmd"]):
        assert a.n_selected == b.n_selected
        assert a.n_processed == b.n_processed
        assert np.array_equal(a.hist, b.hist)
        assert np.array_equal(a.selected_ids, b.selected_ids)
        # different default packetizations regroup the float additions;
        # every decomposition-invariant field above is exact
        assert np.isclose(a.sum_var, b.sum_var, rtol=1e-6)


def test_service_adopts_instance_backend_catalog():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store)
    svc = QueryService(store, backend=spmd)
    assert svc.catalog is spmd.catalog and svc.backend is spmd
    assert svc.jse is None  # no simulation engine behind this service
    with pytest.raises(ValueError, match="share one catalogue"):
        QueryService(store, MetadataCatalog(store.n_nodes), backend=spmd)
    other = make_store(seed=9)
    with pytest.raises(ValueError, match="different brick store"):
        QueryService(other, backend=spmd)


def test_spmd_telemetry_calibrates_cost_model():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=16)
    rows = []
    for calib in (0, 4):
        _, stats, _ = run_window(spmd, store, POOL[:2], calib=calib)
        rows.extend(stats.packet_telemetry)
    assert all(t.wall_s > 0 and t.n_targets == 3 for t in rows)
    weights = fit_cost_weights(rows)
    assert weights.fitted and weights.scale > 0
    # service wiring: a refit lands on the backend for the scheduler
    svc = QueryService(store, backend="spmd", refit_cost_every=1)
    svc.submit(POOL[0]), svc.submit(POOL[1])
    svc.drain()
    assert svc.cost_weights is not None
    assert svc.backend.cost_weights is svc.cost_weights
    assert svc.scheduler.backend is svc.backend
    svc.close()


# ----------------------- window-cost bounding --------------------------- #
def test_window_filled_by_cost_not_count():
    store = make_store(n_events=512)
    sched = QueryScheduler(max_batch=64, window_cost_budget=1100.0)
    svc = QueryService(store, scheduler=sched, use_cache=False)
    for i in range(6):
        svc.submit(f"e_total > {40 + i}")  # cost 512 each (no aggs)
    assert len(sched.next_batch()) == 2    # 512 + 512 <= 1100 < 1536
    assert len(sched.next_batch()) == 2
    svc.close()


def test_window_cost_budget_never_starves():
    store = make_store(n_events=512)
    sched = QueryScheduler(window_cost_budget=10.0)
    svc = QueryService(store, scheduler=sched, use_cache=False)
    svc.submit("e_total > 1"), svc.submit("e_total > 2")
    assert len(sched.next_batch()) == 1    # over-budget query runs alone
    assert len(sched.next_batch()) == 1
    svc.close()


def test_window_cost_recosted_with_fitted_weights():
    from repro.service.planner import CostWeights
    sched = QueryScheduler(max_batch=8, window_cost_budget=1600.0)
    svc = QueryService(make_store(n_events=512), scheduler=sched,
                       use_cache=False)
    for i in range(4):
        svc.submit(f"e_total > {30 + i} && count(pt > {10 + i}) >= 2")
    # static prior: cost = 512 * (1 + 4*1) = 2560 > budget -> one alone
    assert len(sched.next_batch()) == 1
    # a refit that learned aggregates are cheap: 512 * 1.5 = 768 each,
    # so two now fit under the same budget
    svc.backend.cost_weights = CostWeights(agg_weight=0.5, fitted=True)
    assert len(sched.next_batch()) == 2
    svc.close()


def test_window_cost_duplicates_ride_free():
    # the front-end dedups identical canonical queries onto ONE
    # execution, so only the first occurrence charges the window budget
    store = make_store(n_events=512)
    sched = QueryScheduler(max_batch=64, window_cost_budget=600.0)
    svc = QueryService(store, scheduler=sched, use_cache=False)
    for i in range(5):
        svc.submit("e_total > 40", tenant=f"t{i}")   # cost 512, same scan
    svc.submit("e_total > 99", tenant="t5")          # second distinct scan
    window = sched.next_batch()
    assert len(window) == 5                          # dupes free; 512+512
    assert {s.canonical for s in window} == \
        {"(e_total > 40.0)"}                         # > 600 stops the 2nd
    svc.close()


def test_count_cap_still_bounds_cheap_windows():
    sched = QueryScheduler(max_batch=3, window_cost_budget=1e12)
    svc = QueryService(make_store(), scheduler=sched, use_cache=False)
    for i in range(5):
        svc.submit(f"e_total > {i}")
    assert len(sched.next_batch()) == 3    # count cap is the fallback
    svc.close()


# ----------------------- L2 persistence --------------------------------- #
def test_shared_tier_persists_and_survives_restart(tmp_path):
    tier = SharedCacheTier(capacity=8)
    res = merge_lib.from_mask(np.array([1, 0, 1]),
                              np.array([10.0, 20.0, 30.5], np.float32),
                              np.array([7, 8, 9]))
    tier.put("(e_total > 40.0)", 2, 0, res, vv={"fe0": 1})
    path = tmp_path / "l2.json"
    tier.save(path)
    loaded = SharedCacheTier.load(path)
    hit = loaded.get("(e_total > 40.0)", 2, 0, vv={"fe0": 1})
    assert hit is not None and merge_lib.results_identical(hit, res)
    # the persisted join still guards hygiene after the restart: a newer
    # vector advances the join and purges the reloaded entry...
    assert loaded.get("(e_total > 40.0)", 2, 0, vv={"fe0": 2}) is None
    assert loaded.stats.invalidated == 1
    # ...after which the OLD vector is refused as stale
    assert loaded.get("(e_total > 40.0)", 2, 0, vv={"fe0": 1}) is None
    assert loaded.stats.stale_refused == 1


def test_shared_tier_roundtrip_preserves_lru_order_and_join():
    tier = SharedCacheTier(capacity=2)
    r1 = merge_lib.QueryResult(n_selected=1, n_processed=2, sum_var=0.5)
    r2 = merge_lib.QueryResult(n_selected=3, n_processed=4, sum_var=1.5)
    tier.put("a", 0, 1, r1, vv={"fe0": 1})
    tier.put("b", 0, 1, r2, vv={"fe0": 1})
    loaded = SharedCacheTier.from_json(tier.to_json())
    assert len(loaded) == 2
    assert loaded._fp(loaded._join) == tier._fp(tier._join)
    # LRU order survived: inserting one more evicts "a", not "b"
    loaded.put("c", 0, 1, r1, vv={"fe0": 1})
    assert loaded.get("a", 0, 1, vv={"fe0": 1}) is None
    assert loaded.get("b", 0, 1, vv={"fe0": 1}) is not None


def test_query_result_dict_roundtrip_bit_identical():
    rng = np.random.default_rng(3)
    res = merge_lib.from_mask(rng.integers(0, 2, 50),
                              rng.uniform(0, 500, 50).astype(np.float32),
                              rng.integers(0, 10**6, 50))
    back = merge_lib.QueryResult.from_dict(res.to_dict())
    assert merge_lib.results_identical(res, back)
    import json
    via_json = merge_lib.QueryResult.from_dict(
        json.loads(json.dumps(res.to_dict())))
    assert merge_lib.results_identical(res, via_json)


# ----------------------- adaptive gossip fanout ------------------------- #
def test_adaptive_fanout_scales_with_fleet_size():
    assert adaptive_fanout(1) == 1
    assert adaptive_fanout(2) == 1
    assert adaptive_fanout(4) == 2
    assert adaptive_fanout(8) == 3
    assert adaptive_fanout(16) == 4
    assert rounds_bound(16) == 4          # ceil(15/4) with adaptive fanout
    assert rounds_bound(16, 1) == 15      # explicit fanout still honoured
    assert rounds_bound(1) == 0


def test_fleet_defaults_to_adaptive_fanout():
    from repro.fabric import Fleet
    store = make_store()
    fleet = Fleet(store, 4)
    try:
        assert fleet.gossip_fanout == adaptive_fanout(4) == 2
        assert fleet.rounds_bound == rounds_bound(4)
        assert all(len(fe.gossip.targets()) == 2
                   for fe in fleet.frontends)
        # a bump still reaches every peer within the documented bound
        fleet.bump_dataset_version(0)
        fleet.pump(fleet.rounds_bound)
        assert all(fe.catalog.dataset_epoch == 1
                   for fe in fleet.frontends)
    finally:
        fleet.close()
