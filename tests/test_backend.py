"""Unified execution backends: contract equivalence (simulated vs SPMD
chunked streaming scan), prefix-merge bit-identity, Pallas epilogue
fusion of fragment-plan targets, SPMD telemetry feeding cost-model
calibration, window-cost-bounded dispatch, L2 persistence, and adaptive
gossip fanout."""
import numpy as np
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.backend import (SimulatedBackend, SpmdBackend,
                                make_backend)
from repro.core.brick import create_store
from repro.core.catalog import DONE, MetadataCatalog
from repro.fabric import SharedCacheTier, adaptive_fanout, rounds_bound
from repro.service import (QueryScheduler, QueryService, fit_cost_weights,
                           plan_window)

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)

POOL = ["e_total > 40 && count(pt > 15) >= 2",
        "e_total > 30 && count(pt > 15) >= 2",
        "e_t_miss > 25 && count(pt > 15) >= 2",
        "pt_lead > 60 || n_tracks >= 8",
        "e_total > 55 && sum(pt) < 400",
        "e_total + 2 * e_t_miss > 120"]


def make_store(n_events=192, n_nodes=4, seed=7):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=2, seed=seed)


def run_window(backend, store, exprs, *, calib=0, ramp=None):
    plan = plan_window(exprs)
    jids = [backend.catalog.submit(e, calib, tuple(sorted(store.bricks)))
            for e in exprs]
    partials = []
    merged, stats = backend.run_batch(jids, plan=plan,
                                      on_partial=partials.append,
                                      packet_ramp=ramp)
    return merged, stats, partials


def matched_backends(store, chunk=16):
    """A (sim, spmd) pair with IDENTICAL packetization: fixed sim packets
    of ``chunk`` events, spmd chunks of ``chunk`` events."""
    sim = SimulatedBackend(MetadataCatalog(store.n_nodes), store,
                           adaptive_packets=False)
    sim.engine.adaptive_packets = False
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=chunk)
    # the sim's fixed packet size is the scheduler base (64); pin it to
    # the spmd chunk so decompositions line up exactly
    return sim, spmd


def assert_window_equivalent(sim_out, spmd_out):
    (m1, s1, p1), (m2, s2, p2) = sim_out, spmd_out
    assert s1.packets == s2.packets == len(p1) == len(p2)
    for a, b in zip(m1, m2):
        assert merge_lib.results_identical(a, b)
    for pa, pb in zip(p1, p2):
        assert (pa.seq, pa.brick_id, pa.start, pa.size) == \
               (pb.seq, pb.brick_id, pb.start, pb.size)
        assert all(merge_lib.results_identical(a, b)
                   for a, b in zip(pa.partials, pb.partials))
    assert set(s1.fragment_results) == set(s2.fragment_results)
    for key, res in s1.fragment_results.items():
        assert merge_lib.results_identical(res, s2.fragment_results[key])


# ----------------------- contract equivalence --------------------------- #
def test_backends_bit_identical_on_matched_packetization():
    store = make_store()
    sim, spmd = matched_backends(store, chunk=64)
    out1 = run_window(sim, store, POOL, calib=2)
    out2 = run_window(spmd, store, POOL, calib=2)
    assert_window_equivalent(out1, out2)
    # both catalogues converged to DONE with the same result summaries
    for cat in (sim.catalog, spmd.catalog):
        assert all(r.status == DONE for r in cat.jobs.values())


def test_backend_equivalence_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    store = make_store(n_events=96)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 99),
           calib=st.sampled_from([0, 2]),
           k=st.integers(1, 4))
    def check(seed, calib, k):
        rng = np.random.default_rng(seed)
        exprs = [POOL[i] for i in rng.choice(len(POOL), size=k,
                                             replace=False)]
        sim, spmd = matched_backends(store, chunk=64)
        assert_window_equivalent(
            run_window(sim, store, exprs, calib=calib),
            run_window(spmd, store, exprs, calib=calib))

    check()


def test_spmd_prefix_snapshots_bit_identical_to_tree_merge():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=16)
    merged, stats, partials = run_window(spmd, store, POOL[:3])
    assert stats.packets == len(partials) > 1
    for col in range(len(POOL[:3])):
        acc = merge_lib.MergeAccumulator()
        for k, pp in enumerate(partials, 1):
            acc.add(pp.partials[col], brick_id=pp.brick_id)
            want = merge_lib.tree_merge(
                [p.partials[col] for p in partials[:k]])
            assert merge_lib.results_identical(acc.snapshot(), want)
        assert merge_lib.results_identical(acc.snapshot(), merged[col])
    # merge order is deterministic: brick id ascending, offset ascending
    order = [(p.brick_id, p.start) for p in partials]
    assert order == sorted(order)
    # wall-clock availability stamps are non-decreasing
    times = [p.t_virtual for p in partials]
    assert times == sorted(times)


def test_spmd_packet_ramp_caps_early_chunks():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=16)
    _, _, partials = run_window(spmd, store, ["e_total > 40"], ramp=4)
    assert partials[0].size == 4
    assert partials[1].size == 8
    assert max(p.size for p in partials) <= 16


def test_spmd_rejects_failure_script():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store)
    assert not spmd.supports_failure_injection
    jid = spmd.catalog.submit("e_total > 40", 0,
                              tuple(sorted(store.bricks)))
    with pytest.raises(ValueError, match="simulated-grid"):
        spmd.run_batch([jid], failure_script={0.5: 1})


def test_service_rejects_failure_script_before_dequeue():
    store = make_store()
    svc = QueryService(store, backend="spmd")
    tid = svc.submit("e_total > 40", stream=True)
    with pytest.raises(ValueError, match="failure"):
        svc.step(failure_script={1.0: 2})
    # nothing was mutated: the window is still queued, the ticket
    # pending, the stream open — the query runs fine afterwards
    assert svc.scheduler.n_pending == 1
    assert svc.result(tid).status == "QUEUED"
    assert not svc.stream(tid).closed
    svc.step()
    assert svc.result(tid).status == "SERVED"
    assert svc.stream(tid).done
    svc.close()


def test_service_rejects_simulation_knobs_on_spmd_backend():
    from repro.core.jse import TimeModel
    store = make_store()
    with pytest.raises(ValueError, match="simulation knobs"):
        QueryService(store, backend="spmd", time_model=TimeModel())
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store)
    with pytest.raises(ValueError, match="pre-built instance"):
        QueryService(store, backend=spmd, node_speed={0: 0.5})


def test_make_backend_factory():
    store = make_store()
    cat = MetadataCatalog(store.n_nodes)
    assert isinstance(make_backend("sim", cat, store), SimulatedBackend)
    assert isinstance(make_backend("spmd", cat, store), SpmdBackend)
    with pytest.raises(ValueError, match="unknown backend"):
        make_backend("tpu", cat, store)


# ----------------------- Pallas epilogue fusion ------------------------- #
def test_match_epilogue_relaxed_family():
    from repro.kernels.event_filter import ops as ef_ops
    full = ef_ops.match_epilogue(
        "e_total > 40 && count(pt > 15) >= 2 && sum(pt) < 400", SCHEMA)
    assert full["scalar_thresh"] == 40 and full["min_count"] == 2 \
        and full["sum_cap"] == 400
    bare_count = ef_ops.match_epilogue("count(pt > 15) >= 2", SCHEMA)
    assert bare_count is not None
    assert bare_count["scalar_thresh"] == float("-inf")
    assert bare_count["min_count"] == 2
    lone_scalar = ef_ops.match_epilogue("e_t_miss > 25", SCHEMA)
    assert lone_scalar is not None and lone_scalar["min_count"] == 0
    # outside the conjunctive family
    assert ef_ops.match_epilogue("pt_lead > 60 || n_tracks >= 8",
                                 SCHEMA) is None
    assert ef_ops.match_epilogue("e_total + 2 * e_t_miss > 120",
                                 SCHEMA) is None
    assert ef_ops.match_epilogue("sum(pt) < 0", SCHEMA) is None  # aliases
    assert ef_ops.match_epilogue("nope > 3", SCHEMA) is None


def test_spmd_pallas_fusion_matches_jnp_plan():
    store = make_store(n_events=96)
    exprs = POOL[:3]  # shared count fragment -> materialized target
    plan = plan_window(exprs)
    assert plan.materialize, "expected a materialized shared fragment"
    ref = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                      chunk_events=32)
    fused = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        chunk_events=32, use_pallas=True)
    # the fusion hook actually engages for this window (every target —
    # roots AND the materialized boolean fragment — is in-family)
    assert fused._fuse_plan(plan) is not None
    out_ref = run_window(ref, store, exprs, calib=2)
    out_fused = run_window(fused, store, exprs, calib=2)
    assert_window_equivalent(out_ref, out_fused)


def test_spmd_pallas_falls_back_on_out_of_family_target():
    store = make_store(n_events=64)
    fused = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                        use_pallas=True)
    plan = plan_window(["pt_lead > 60 || n_tracks >= 8"])
    assert fused._fuse_plan(plan) is None
    merged, _, _ = run_window(fused, store,
                              ["pt_lead > 60 || n_tracks >= 8"])
    sim = SimulatedBackend(MetadataCatalog(store.n_nodes), store,
                           adaptive_packets=False)
    want, _, _ = run_window(sim, store, ["pt_lead > 60 || n_tracks >= 8"])
    assert merge_lib.results_identical(merged[0], want[0])


# ----------------------- service integration ---------------------------- #
def test_service_backend_agnostic_end_to_end():
    store = make_store()
    results = {}
    for kind in ("sim", "spmd"):
        svc = QueryService(store, backend=kind, use_cache=True)
        tid = svc.submit(POOL[0], stream=True)
        tid2 = svc.submit(POOL[3])
        svc.drain()
        t = svc.result(tid)
        assert t.status == "SERVED"
        stream = svc.stream(tid)
        assert stream.done and stream.latest().final
        assert merge_lib.results_identical(stream.latest().result,
                                           t.result)
        assert stream.latest().coverage.complete
        # repeat submission is a zero-I/O cache hit on either backend
        tid3 = svc.submit(POOL[0])
        assert svc.result(tid3).from_cache
        results[kind] = (t.result, svc.result(tid2).result)
        svc.close()
    for a, b in zip(results["sim"], results["spmd"]):
        assert a.n_selected == b.n_selected
        assert a.n_processed == b.n_processed
        assert np.array_equal(a.hist, b.hist)
        assert np.array_equal(a.selected_ids, b.selected_ids)
        # different default packetizations regroup the float additions;
        # every decomposition-invariant field above is exact
        assert np.isclose(a.sum_var, b.sum_var, rtol=1e-6)


def test_service_adopts_instance_backend_catalog():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store)
    svc = QueryService(store, backend=spmd)
    assert svc.catalog is spmd.catalog and svc.backend is spmd
    assert svc.jse is None  # no simulation engine behind this service
    with pytest.raises(ValueError, match="share one catalogue"):
        QueryService(store, MetadataCatalog(store.n_nodes), backend=spmd)
    other = make_store(seed=9)
    with pytest.raises(ValueError, match="different brick store"):
        QueryService(other, backend=spmd)


def test_spmd_telemetry_calibrates_cost_model():
    store = make_store()
    spmd = SpmdBackend(MetadataCatalog(store.n_nodes), store,
                       chunk_events=16)
    rows = []
    for calib in (0, 4):
        _, stats, _ = run_window(spmd, store, POOL[:2], calib=calib)
        rows.extend(stats.packet_telemetry)
    assert all(t.wall_s > 0 and t.n_targets == 3 for t in rows)
    weights = fit_cost_weights(rows)
    assert weights.fitted and weights.scale > 0
    # service wiring: a refit lands on the backend for the scheduler
    svc = QueryService(store, backend="spmd", refit_cost_every=1)
    svc.submit(POOL[0]), svc.submit(POOL[1])
    svc.drain()
    assert svc.cost_weights is not None
    assert svc.backend.cost_weights is svc.cost_weights
    assert svc.scheduler.backend is svc.backend
    svc.close()


# ----------------------- window-cost bounding --------------------------- #
def test_window_filled_by_cost_not_count():
    store = make_store(n_events=512)
    sched = QueryScheduler(max_batch=64, window_cost_budget=1100.0)
    svc = QueryService(store, scheduler=sched, use_cache=False)
    for i in range(6):
        svc.submit(f"e_total > {40 + i}")  # cost 512 each (no aggs)
    assert len(sched.next_batch()) == 2    # 512 + 512 <= 1100 < 1536
    assert len(sched.next_batch()) == 2
    svc.close()


def test_window_cost_budget_never_starves():
    store = make_store(n_events=512)
    sched = QueryScheduler(window_cost_budget=10.0)
    svc = QueryService(store, scheduler=sched, use_cache=False)
    svc.submit("e_total > 1"), svc.submit("e_total > 2")
    assert len(sched.next_batch()) == 1    # over-budget query runs alone
    assert len(sched.next_batch()) == 1
    svc.close()


def test_window_cost_recosted_with_fitted_weights():
    from repro.service.planner import CostWeights
    sched = QueryScheduler(max_batch=8, window_cost_budget=1600.0)
    svc = QueryService(make_store(n_events=512), scheduler=sched,
                       use_cache=False)
    for i in range(4):
        svc.submit(f"e_total > {30 + i} && count(pt > {10 + i}) >= 2")
    # static prior: cost = 512 * (1 + 4*1) = 2560 > budget -> one alone
    assert len(sched.next_batch()) == 1
    # a refit that learned aggregates are cheap: 512 * 1.5 = 768 each,
    # so two now fit under the same budget
    svc.backend.cost_weights = CostWeights(agg_weight=0.5, fitted=True)
    assert len(sched.next_batch()) == 2
    svc.close()


def test_window_cost_duplicates_ride_free():
    # the front-end dedups identical canonical queries onto ONE
    # execution, so only the first occurrence charges the window budget
    store = make_store(n_events=512)
    sched = QueryScheduler(max_batch=64, window_cost_budget=600.0)
    svc = QueryService(store, scheduler=sched, use_cache=False)
    for i in range(5):
        svc.submit("e_total > 40", tenant=f"t{i}")   # cost 512, same scan
    svc.submit("e_total > 99", tenant="t5")          # second distinct scan
    window = sched.next_batch()
    assert len(window) == 5                          # dupes free; 512+512
    assert {s.canonical for s in window} == \
        {"(e_total > 40.0)"}                         # > 600 stops the 2nd
    svc.close()


def test_count_cap_still_bounds_cheap_windows():
    sched = QueryScheduler(max_batch=3, window_cost_budget=1e12)
    svc = QueryService(make_store(), scheduler=sched, use_cache=False)
    for i in range(5):
        svc.submit(f"e_total > {i}")
    assert len(sched.next_batch()) == 3    # count cap is the fallback
    svc.close()


# ----------------------- L2 persistence --------------------------------- #
def test_shared_tier_persists_and_survives_restart(tmp_path):
    tier = SharedCacheTier(capacity=8)
    res = merge_lib.from_mask(np.array([1, 0, 1]),
                              np.array([10.0, 20.0, 30.5], np.float32),
                              np.array([7, 8, 9]))
    tier.put("(e_total > 40.0)", 2, 0, res, vv={"fe0": 1})
    path = tmp_path / "l2.json"
    tier.save(path)
    loaded = SharedCacheTier.load(path)
    hit = loaded.get("(e_total > 40.0)", 2, 0, vv={"fe0": 1})
    assert hit is not None and merge_lib.results_identical(hit, res)
    # the persisted join still guards hygiene after the restart: a newer
    # vector advances the join and purges the reloaded entry...
    assert loaded.get("(e_total > 40.0)", 2, 0, vv={"fe0": 2}) is None
    assert loaded.stats.invalidated == 1
    # ...after which the OLD vector is refused as stale
    assert loaded.get("(e_total > 40.0)", 2, 0, vv={"fe0": 1}) is None
    assert loaded.stats.stale_refused == 1


def test_shared_tier_roundtrip_preserves_lru_order_and_join():
    tier = SharedCacheTier(capacity=2)
    r1 = merge_lib.QueryResult(n_selected=1, n_processed=2, sum_var=0.5)
    r2 = merge_lib.QueryResult(n_selected=3, n_processed=4, sum_var=1.5)
    tier.put("a", 0, 1, r1, vv={"fe0": 1})
    tier.put("b", 0, 1, r2, vv={"fe0": 1})
    loaded = SharedCacheTier.from_json(tier.to_json())
    assert len(loaded) == 2
    assert loaded._fp(loaded._join) == tier._fp(tier._join)
    # LRU order survived: inserting one more evicts "a", not "b"
    loaded.put("c", 0, 1, r1, vv={"fe0": 1})
    assert loaded.get("a", 0, 1, vv={"fe0": 1}) is None
    assert loaded.get("b", 0, 1, vv={"fe0": 1}) is not None


def test_query_result_dict_roundtrip_bit_identical():
    rng = np.random.default_rng(3)
    res = merge_lib.from_mask(rng.integers(0, 2, 50),
                              rng.uniform(0, 500, 50).astype(np.float32),
                              rng.integers(0, 10**6, 50))
    back = merge_lib.QueryResult.from_dict(res.to_dict())
    assert merge_lib.results_identical(res, back)
    import json
    via_json = merge_lib.QueryResult.from_dict(
        json.loads(json.dumps(res.to_dict())))
    assert merge_lib.results_identical(res, via_json)


# ----------------------- adaptive gossip fanout ------------------------- #
def test_adaptive_fanout_scales_with_fleet_size():
    assert adaptive_fanout(1) == 1
    assert adaptive_fanout(2) == 1
    assert adaptive_fanout(4) == 2
    assert adaptive_fanout(8) == 3
    assert adaptive_fanout(16) == 4
    assert rounds_bound(16) == 4          # ceil(15/4) with adaptive fanout
    assert rounds_bound(16, 1) == 15      # explicit fanout still honoured
    assert rounds_bound(1) == 0


def test_fleet_defaults_to_adaptive_fanout():
    from repro.fabric import Fleet
    store = make_store()
    fleet = Fleet(store, 4)
    try:
        assert fleet.gossip_fanout == adaptive_fanout(4) == 2
        assert fleet.rounds_bound == rounds_bound(4)
        assert all(len(fe.gossip.targets()) == 2
                   for fe in fleet.frontends)
        # a bump still reaches every peer within the documented bound
        fleet.bump_dataset_version(0)
        fleet.pump(fleet.rounds_bound)
        assert all(fe.catalog.dataset_epoch == 1
                   for fe in fleet.frontends)
    finally:
        fleet.close()
