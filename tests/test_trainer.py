"""End-to-end trainer behaviour: loss goes down, checkpoints land, restart
resumes from the checkpoint, and a simulated node failure mid-run doesn't
change batch content (replica failover is exact)."""
import jax
import numpy as np

from repro.configs.registry import reduced_config
from repro.launch.mesh import make_mesh_of
from repro.train.trainer import Trainer, TrainerConfig


def _mk(tmp_path, total_steps=12, failure_hook=None):
    cfg = reduced_config("qwen3-14b", microbatches=1)
    mesh = make_mesh_of((1, 1), ("data", "model"))
    tcfg = TrainerConfig(total_steps=total_steps, ckpt_every=5,
                         ckpt_dir=str(tmp_path / "ckpt"), global_batch=4,
                         seq_len=32, log_every=2, async_ckpt=False)
    return Trainer(cfg, tcfg, mesh, failure_hook=failure_hook)


def test_trainer_runs_and_checkpoints(tmp_path):
    tr = _mk(tmp_path)
    out = tr.train()
    assert out["steps"] == 12
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0]  # learns the synthetic stream a bit
    from repro.checkpoint.ckpt import latest_step
    assert latest_step(tmp_path / "ckpt") == 12


def test_trainer_restart_resumes(tmp_path):
    tr = _mk(tmp_path, total_steps=6)
    tr.train()
    tr2 = _mk(tmp_path, total_steps=10)
    out = tr2.train()
    assert out["steps"] == 4  # resumed from step 6, ran 4 more


def test_trainer_survives_data_node_failure(tmp_path):
    kills = {4: 1}
    tr = _mk(tmp_path, failure_hook=lambda step: kills.pop(step, None))
    out = tr.train()
    assert out["steps"] == 12  # no crash, batches kept flowing
