"""GEPS core behaviour: query compiler, bricks, JSE, merge, packets,
replication, failover, elasticity."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.brick import create_store, gather_store
from repro.core.catalog import DONE, FAILED, MetadataCatalog
from repro.core.elastic import ElasticManager, elastic_mesh_shape
from repro.core.jse import JobSubmissionEngine, TimeModel, spmd_query_step
from repro.core.packets import AdaptivePacketScheduler
from repro.core.replication import failover_owner, place_replicas

CFG = reduced()
SCHEMA = ev.EventSchema.from_config(CFG)


def make_store(n_events=128, n_nodes=4, replication=2):
    return create_store(SCHEMA, n_events=n_events, n_nodes=n_nodes,
                        events_per_brick=CFG.events_per_brick,
                        replication=replication, seed=7)


# ---------------------------- query compiler ----------------------------- #
def test_query_simple_threshold():
    store = make_store()
    batch = gather_store(store)
    fn = query_lib.compile_query("e_total > 40", SCHEMA)
    mask = np.asarray(fn({k: jnp.asarray(v) for k, v in batch.items()}))
    np.testing.assert_array_equal(mask != 0, batch["scalars"][:, 0] > 40)


def test_query_aggregations_and_logic():
    store = make_store()
    batch = gather_store(store)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    fn = query_lib.compile_query(
        "count(pt > 15) >= 2 && sum(pt) < 800 || n_tracks == 1", SCHEMA)
    mask = np.asarray(fn(jb)) != 0
    t = np.arange(SCHEMA.max_tracks)[None, :] < batch["n_tracks"][:, None]
    pt = batch["tracks"][:, :, 0]
    cnt = ((pt > 15) & t).sum(-1)
    ssum = np.where(t, pt, 0).sum(-1)
    expect = ((cnt >= 2) & (ssum < 800)) | (batch["n_tracks"] == 1)
    np.testing.assert_array_equal(mask, expect)


def test_query_arithmetic_precedence():
    store = make_store(n_events=32)
    batch = gather_store(store)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    fn = query_lib.compile_query("e_total + 2 * e_t_miss > 100", SCHEMA)
    mask = np.asarray(fn(jb)) != 0
    s = batch["scalars"]
    np.testing.assert_array_equal(mask, s[:, 0] + 2 * s[:, 1] > 100)


def test_query_errors():
    with pytest.raises(query_lib.QueryError):
        query_lib.compile_query("nonsense_var > 1", SCHEMA)({})
    with pytest.raises(query_lib.QueryError):
        query_lib.parse("e_total >")


# ---------------------------- bricks / replication ----------------------- #
def test_brick_partition_covers_all_events():
    store = make_store(n_events=100)
    assert store.n_events == 100
    ids = np.sort(gather_store(store)["event_id"])
    np.testing.assert_array_equal(ids, np.arange(100))


def test_replica_placement_disjoint():
    for bid in range(16):
        node = bid % 5
        reps = place_replicas(bid, node, 5, 3)
        assert node not in reps and len(set(reps)) == len(reps) == 2


def test_failover_owner():
    assert failover_owner([1, 3, 4], {1}) == 3
    assert failover_owner([1, 3], {1, 3}) == -1


# ---------------------------- JSE end to end ----------------------------- #
def test_jse_job_matches_oracle():
    store = make_store()
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    jid = jse.submit("e_total > 40")
    merged, stats = jse.run_job_simulated(jid)
    batch = gather_store(store)
    expect = int((batch["scalars"][:, 0] > 40).sum())
    assert merged.n_selected == expect
    assert merged.n_processed == store.n_events
    assert cat.jobs[jid].status == DONE
    assert stats.makespan_s > 0


def test_jse_survives_node_failure_with_replicas():
    store = make_store(n_events=256, n_nodes=4, replication=2)
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    jid = jse.submit("e_total > 40")
    # node 1 dies mid-job (virtual time 0.5 s)
    merged, stats = jse.run_job_simulated(jid, failure_script={0.5: 1})
    batch = gather_store(store)
    expect = int((batch["scalars"][:, 0] > 40).sum())
    assert merged.n_selected == expect  # no events lost
    assert cat.jobs[jid].status == DONE


def test_jse_fails_without_replicas_when_node_dies_before_job():
    store = make_store(n_events=256, n_nodes=4, replication=1)
    cat = MetadataCatalog(store.n_nodes)
    cat.mark_dead(1)
    jse = JobSubmissionEngine(cat, store)
    jid = jse.submit("e_total > 40")
    merged, _ = jse.run_job_simulated(jid)
    assert cat.jobs[jid].status == FAILED  # the paper's known weakness


def test_spmd_query_step_matches_host_path():
    store = make_store()
    batch = gather_store(store)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    step = spmd_query_step("e_total > 40", SCHEMA)
    out = step(jb)
    cat = MetadataCatalog(store.n_nodes)
    jse = JobSubmissionEngine(cat, store)
    jid = jse.submit("e_total > 40")
    merged, _ = jse.run_job_simulated(jid)
    assert int(out["n_selected"]) == merged.n_selected
    assert np.isclose(float(out["sum_var"]), merged.sum_var, rtol=1e-5)
    np.testing.assert_array_equal(
        np.asarray(out["hist"], np.int64), merged.hist)


# ---------------------------- merge ----------------------------- #
def test_tree_merge_associative():
    rng = np.random.default_rng(0)
    parts = []
    for i in range(7):
        mask = rng.integers(0, 2, 50)
        var = rng.uniform(0, 500, 50).astype(np.float32)
        ids = np.arange(i * 50, (i + 1) * 50)
        parts.append(merge_lib.from_mask(mask, var, ids))
    t = merge_lib.tree_merge(parts)
    lin = parts[0]
    for p in parts[1:]:
        lin = merge_lib.merge2(lin, p)
    assert t.n_selected == lin.n_selected
    assert np.isclose(t.sum_var, lin.sum_var)
    np.testing.assert_array_equal(t.hist, lin.hist)


# ---------------------------- packets ----------------------------- #
def test_adaptive_packets_scale_with_speed():
    cat = MetadataCatalog(3)
    cat.node(0).throughput_ema = 4.0
    cat.node(1).throughput_ema = 1.0
    cat.node(2).throughput_ema = 1.0
    sched = AdaptivePacketScheduler(cat, base_packet=60)
    sched.add_work(0, 10_000)
    fast = sched.next_packet(0)
    slow = sched.next_packet(1)
    assert fast.size > slow.size


def test_packet_failure_requeue_preserves_work():
    cat = MetadataCatalog(2)
    sched = AdaptivePacketScheduler(cat, base_packet=16)
    sched.add_work(0, 64)
    done = 0
    pkt = sched.next_packet(0)
    sched.fail(pkt.packet_id, node_dead=True)  # node 0 dies
    while not sched.exhausted:
        pkt = sched.next_packet(1)
        assert pkt is not None
        sched.complete(pkt.packet_id, pkt.size, 0.1)
        done += pkt.size
    assert done == 64  # every event processed exactly once


# ---------------------------- elastic ----------------------------- #
def test_elastic_node_leave_and_rejoin():
    store = make_store(n_events=256, n_nodes=4, replication=2)
    cat = MetadataCatalog(store.n_nodes)
    em = ElasticManager(cat, store)
    plan = em.node_leave(2)
    assert not plan.lost_bricks
    assert all(old == 2 for _, old, _ in plan.reassign_primary)
    em.apply_copies(plan)
    # after re-replication every brick has an alive owner set
    dead = cat.dead_nodes()
    for bid in store.specs:
        assert failover_owner(store.owners(bid), dead) >= 0
    plan2 = em.node_join(2)
    assert isinstance(plan2.reassign_primary, list)


def test_elastic_mesh_shape():
    assert elastic_mesh_shape(256) == (16, 16)
    assert elastic_mesh_shape(255) == (8, 16)
    assert elastic_mesh_shape(512, pods=2) == (2, 16, 16)
    assert elastic_mesh_shape(8) is None


def test_catalog_persistence_roundtrip():
    cat = MetadataCatalog(3)
    jid = cat.submit("e_total > 1", 2, (0, 1))
    cat.update(jid, status=DONE, events_processed=10)
    cat.node(1).observe(100, 2.0)
    cat2 = MetadataCatalog.from_json(cat.to_json())
    assert cat2.jobs[jid].status == DONE
    assert cat2.jobs[jid].bricks == (0, 1)
    assert cat2.nodes[1].throughput_ema == cat.nodes[1].throughput_ema
