"""Flight recorder + deterministic replay: the record -> replay
bit-identity contract under drops, partitions, epoch bumps and node
death; recording's exact-zero virtual-clock overhead; log validation;
and the Prometheus/trace satellite surfaces."""
import pytest

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core.brick import create_store
from repro.fabric.bus import MessageBus
from repro.fabric.fleet import Fleet
from repro.obs import flight as flight_lib
from repro.obs import replay as replay_lib
from repro.obs import trace as trace_lib

N_EVENTS, N_NODES, EPB = 400, 4, 40


def mkstore(seed=7):
    schema = ev.EventSchema.from_config(reduced())
    return create_store(schema, n_events=N_EVENTS, n_nodes=N_NODES,
                        events_per_brick=EPB, replication=2, seed=seed)


def faulty_run(*, drop_rate=0.2, bus_seed=3, partition=True, bump=True,
               kill=True, store=None, flight=True):
    """A fleet-of-4 run exercising every nondeterminism-relevant path:
    seeded drops, a partition + heal, a mid-run epoch bump, a grid-node
    death, streams and single-flight adoption.  Returns (fleet-closed
    flight records, final results by gtid, comparable trace records)."""
    store = store if store is not None else mkstore()
    bus = MessageBus(drop_rate=drop_rate, seed=bus_seed)
    fleet = Fleet(store, 4, bus=bus, obs=True, single_flight=True,
                  flight=flight)
    gtids = [fleet.submit("e_total > 40", tenant="a", stream=True),
             fleet.submit("e_total > 40", tenant="b", stream=True),
             fleet.submit("e_t_miss > 30", tenant="c")]
    fleet.step(0)
    if partition:
        # bus-level fault injected OUTSIDE the driver-op log: replay
        # covers it wholesale through the scripted send outcomes
        fleet.bus.partition({"fe0", "fe1"}, {"fe2", "fe3"})
        fleet.pump(2)
        fleet.bus.heal()
    if bump:
        fleet.bump_dataset_version(0)
    if kill:
        fleet.node_leave(1, observed_by=0)
    gtids.append(fleet.submit("e_total > 40", tenant="a"))
    fleet.drain()
    results = {g: fleet.result(g).result for g in gtids}
    trace = trace_lib.comparable_records(fleet.trace_records())
    records = list(fleet.flight.records) if flight else None
    fleet.close()
    return records, results, trace


def test_record_replay_bit_identical_under_faults():
    records, _, trace = faulty_run()
    assert not flight_lib.validate_flight(records)
    # the original store was mutated (node death -> failover,
    # migration): replay MUST drive an equal FRESH store
    report = replay_lib.replay_run(records, store=mkstore())
    assert report.identical, (report.mismatches, report.bus_divergences)
    assert report.overruns == 0
    # stronger than the contract: the replay's own log is byte-equal
    assert report.records == records
    # and the span timeline (wall stamps stripped) matches exactly
    assert trace_lib.comparable_records(report.trace) == trace


def test_recording_is_deterministic():
    a, _, _ = faulty_run()
    b, _, _ = faulty_run()
    assert a == b


def test_flight_leaves_virtual_timeline_exactly_unchanged():
    store_on, store_off = mkstore(), mkstore()
    _, res_on, trace_on = faulty_run(store=store_on, flight=True)
    _, res_off, trace_off = faulty_run(store=store_off, flight=False)
    assert set(res_on) == set(res_off)
    for g in res_on:
        assert merge_lib.results_identical(res_on[g], res_off[g])
    # every span — window makespans included — identical, so the
    # recorder's virtual-clock overhead is exactly zero
    assert trace_on == trace_off


def test_replay_flags_tampered_final():
    records, _, _ = faulty_run()
    tampered = [dict(r) for r in records]
    for rec in tampered:
        if rec["kind"] == "final" and rec.get("digest"):
            rec["digest"] = "0" * 16
            break
    report = replay_lib.replay_run(tampered, store=mkstore())
    assert not report.identical
    assert any("final" in m for m in report.mismatches)


def test_replay_flags_script_divergence():
    records, _, _ = faulty_run()
    tampered = [dict(r) for r in records]
    sends = [r for r in tampered if r["kind"] == "bus_send"]
    sends[len(sends) // 2]["src"] = "fe999"
    report = replay_lib.replay_run(tampered, store=mkstore())
    assert report.bus_divergences
    assert not report.identical


def test_replay_refuses_bad_logs(tmp_path):
    records, _, _ = faulty_run(partition=False, bump=False, kill=False)
    with pytest.raises(replay_lib.ReplayError):
        replay_lib.replay_run(records[2:])  # non-contiguous eids
    with pytest.raises(replay_lib.ReplayError):
        replay_lib.replay_run(
            [r for r in records if r["kind"] != "run_header"],
            store=mkstore())
    with pytest.raises(replay_lib.ReplayError):
        replay_lib.replay_run(records)  # no store_config, no store=


def test_save_load_roundtrip_and_validation(tmp_path):
    records, _, _ = faulty_run(partition=False, bump=False, kill=False)
    path = tmp_path / "flight.jsonl"
    flight_lib.save_flight(records, path)
    assert flight_lib.load_flight(path) == records
    bad = [dict(r) for r in records]
    bad[5]["kind"] = "warp_core_breach"
    bad[6]["cause"] = 10 ** 9
    bad[7]["schema"] = 99
    problems = flight_lib.validate_flight(bad)
    assert len(problems) == 3


def test_cause_chain_reaches_driver_op():
    records, _, _ = faulty_run()
    grants = [r for r in records if r["kind"] == "lease_grant"
              and r["cause"] is not None]
    assert grants
    rec = grants[-1]
    seen = []
    while rec["cause"] is not None:
        seen.append(rec["kind"])
        rec = records[rec["cause"]]
    assert rec["kind"] == "op"


def test_prom_text_exposition():
    from repro.obs import MetricsRegistry
    reg = MetricsRegistry(origin="fe0")
    reg.counter("bus.sent").inc(5)
    reg.gauge("queue.depth").set(3)
    h = reg.histogram("window.makespan_s", edges=(1.0, 2.0))
    for v in (0.5, 1.5, 9.0):
        h.observe(v)
    text = reg.snapshot().to_prom_text()
    assert "# TYPE bus_sent counter\nbus_sent 5.0" in text
    assert "# TYPE queue_depth gauge\nqueue_depth 3.0" in text
    assert 'window_makespan_s_bucket{le="1.0"} 1' in text
    assert 'window_makespan_s_bucket{le="2.0"} 2' in text
    assert 'window_makespan_s_bucket{le="+Inf"} 3' in text
    assert "window_makespan_s_count 3" in text


def test_trace_schema_accepts_lease_key_ticket():
    tr = trace_lib.Tracer(process="fe0")
    tr.event("stream_partial", ticket="lease:(e_total > 40.0)|c0|",
             seq=1, col=0)
    tr.event("final", ticket=7, outcome="SERVED")
    records = tr.records()
    assert not trace_lib.validate_records(records)
    chrome = trace_lib.chrome_from_records(records)
    lanes = [e["tid"] for e in chrome["traceEvents"]]
    assert lanes == [-1, 7]  # string tickets share the -1 lane
    assert chrome["traceEvents"][0]["args"]["ticket"].startswith("lease:")


def test_hypothesis_record_replay_identity():
    hypothesis = pytest.importorskip("hypothesis")
    st = hypothesis.strategies

    @hypothesis.settings(max_examples=8, deadline=None)
    @hypothesis.given(drop=st.sampled_from([0.0, 0.15, 0.35]),
                      seed=st.integers(0, 99),
                      partition=st.booleans(), bump=st.booleans(),
                      kill=st.booleans())
    def prop(drop, seed, partition, bump, kill):
        records, results, trace = faulty_run(
            drop_rate=drop, bus_seed=seed, partition=partition,
            bump=bump, kill=kill)
        report = replay_lib.replay_run(records, store=mkstore())
        assert report.identical, (report.mismatches,
                                  report.bus_divergences)
        assert report.records == records
        assert trace_lib.comparable_records(report.trace) == trace
        # replayed finals are bit-identical, not just digest-equal
        finals = {r["gtid"]: r for r in report.records
                  if r["kind"] == "final"}
        for g, res in results.items():
            if res is not None:
                assert finals[g]["digest"] == \
                    flight_lib.result_digest(res)

    prop()
