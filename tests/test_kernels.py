"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles,
executed in Pallas interpret mode (the kernel bodies run in Python on CPU;
the BlockSpecs/grids are the TPU-target artifacts)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.event_filter.kernel import event_filter_pallas
from repro.kernels.event_filter.ref import event_filter_ref
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mlstm_scan.kernel import mlstm_pallas
from repro.kernels.mlstm_scan.ref import mlstm_ref
from repro.kernels.rglru_scan.kernel import rglru_scan_pallas
from repro.kernels.rglru_scan.ref import rglru_scan_ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)


# ------------------------------ flash attention -------------------------- #
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,sk,h,kh,d,bq,bk", [
    (1, 64, 64, 4, 4, 32, 16, 16),     # MHA square
    (2, 128, 128, 8, 2, 64, 32, 64),   # GQA 4:1
    (1, 96, 96, 4, 1, 32, 32, 32),     # MQA, non-pow2 seq
    (2, 32, 128, 4, 2, 16, 16, 32),    # cross Sq < Sk (decode-ish)
])
def test_flash_attention_sweep(b, sq, sk, h, kh, d, bq, bk, dtype):
    q = jnp.asarray(RNG.normal(size=(b, sq, h, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, sk, kh, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, sk, kh, d)), dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 40])
def test_flash_attention_window(window):
    q = jnp.asarray(RNG.normal(size=(1, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 128, 2, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=32, block_k=32)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    q = jnp.asarray(RNG.normal(size=(2, 64, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 64, 4, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 64, 4, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=True, logit_cap=30.0,
                          block_q=16, block_k=16)
    ref = flash_attention_ref(q, k, v, causal=True, logit_cap=30.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------ event filter ----------------------------- #
@pytest.mark.parametrize("n,t,v,be,bt", [
    (128, 64, 7, 64, 32),
    (300, 70, 5, 128, 16),   # partial blocks both axes
    (64, 16, 3, 64, 16),     # single block
])
@pytest.mark.parametrize("calib_iters", [0, 3])
def test_event_filter_sweep(n, t, v, be, bt, calib_iters):
    scalars = jnp.asarray(np.abs(RNG.normal(size=(n, 8)) * 50), jnp.float32)
    tracks = jnp.asarray(RNG.normal(size=(n, t, v)), jnp.float32)
    tracks = tracks.at[:, :, 0].set(
        jnp.asarray(RNG.exponential(size=(n, t)) * 10))
    n_tracks = jnp.asarray(RNG.integers(1, t + 1, size=(n,)), jnp.int32)
    th = jnp.array([40.0, 15.0, 2.0, 800.0], jnp.float32)
    mask, var = event_filter_pallas(scalars, tracks, n_tracks, th,
                                    var_idx=0, calib_iters=calib_iters,
                                    block_e=be, block_t=bt)
    mask_r, var_r = event_filter_ref(
        scalars, tracks, n_tracks, var_idx=0, scalar_thresh=40.0,
        pt_thresh=15.0, min_count=2.0, sum_cap=800.0,
        calib_iters=calib_iters)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_r))
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_r))


def test_event_filter_no_sum_cap():
    n, t, v = 64, 32, 4
    scalars = jnp.asarray(np.abs(RNG.normal(size=(n, 8)) * 50), jnp.float32)
    tracks = jnp.asarray(RNG.normal(size=(n, t, v)), jnp.float32)
    n_tracks = jnp.asarray(RNG.integers(1, t + 1, size=(n,)), jnp.int32)
    th = jnp.array([40.0, 0.0, 1.0, -1.0], jnp.float32)  # cap disabled
    mask, _ = event_filter_pallas(scalars, tracks, n_tracks, th, var_idx=0,
                                  calib_iters=0, block_e=32, block_t=16)
    mask_r, _ = event_filter_ref(scalars, tracks, n_tracks, var_idx=0,
                                 scalar_thresh=40.0, pt_thresh=0.0,
                                 min_count=1.0, sum_cap=-1.0, calib_iters=0)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_r))


def _ef_operands(n=64, t=32, v=4, s=8):
    scalars = jnp.asarray(np.abs(RNG.normal(size=(n, s)) * 50), jnp.float32)
    tracks = jnp.asarray(RNG.normal(size=(n, t, v)), jnp.float32)
    n_tracks = jnp.asarray(RNG.integers(1, t + 1, size=(n,)), jnp.int32)
    return scalars, tracks, n_tracks


def test_event_filter_rejects_zero_sized_inputs():
    """Empty operands must fail with a clear ValueError at validation,
    not a Pallas trace error from a zero-width grid."""
    from repro.kernels.event_filter.kernel import event_filter_batch_pallas
    scalars, tracks, n_tracks = _ef_operands()
    th = jnp.array([[40.0], [15.0], [2.0], [-1.0]], jnp.float32)
    with pytest.raises(ValueError, match="zero-width grid"):
        event_filter_pallas(scalars[:0], tracks[:0], n_tracks[:0],
                            jnp.array([40.0, 15.0, 2.0, -1.0]),
                            var_idx=0, calib_iters=0)
    with pytest.raises(ValueError, match="zero-width grid"):
        event_filter_batch_pallas(scalars, tracks[:, :0], n_tracks, th,
                                  var_idx=(0,), calib_iters=0)
    with pytest.raises(ValueError, match="thresholds"):
        event_filter_batch_pallas(scalars, tracks, n_tracks, th[:, :0],
                                  var_idx=(), calib_iters=0)


def test_event_filter_validates_shapes_and_blocks():
    from repro.kernels.event_filter.kernel import event_filter_batch_pallas
    scalars, tracks, n_tracks = _ef_operands()
    th1 = jnp.array([40.0, 15.0, 2.0, -1.0], jnp.float32)
    thb = jnp.array([[40.0], [15.0], [2.0], [-1.0]], jnp.float32)
    with pytest.raises(ValueError, match="event axis"):
        event_filter_pallas(scalars[:32], tracks, n_tracks, th1,
                            var_idx=0, calib_iters=0)
    with pytest.raises(ValueError, match="block"):
        event_filter_pallas(scalars, tracks, n_tracks, th1,
                            var_idx=0, calib_iters=0, block_e=0)
    with pytest.raises(ValueError, match="thresholds"):
        event_filter_batch_pallas(scalars, tracks, n_tracks, th1,
                                  var_idx=(0,), calib_iters=0)


def test_event_filter_tail_masking_vs_padded_duplicate():
    """The tail tile is masked explicitly: appending garbage rows past
    the true event count must not change the valid rows' outputs."""
    n, t = 100, 70    # neither a multiple of its block
    scalars, tracks, n_tracks = _ef_operands(n=n, t=t)
    th = jnp.array([40.0, 15.0, 2.0, 800.0], jnp.float32)
    mask, var = event_filter_pallas(scalars, tracks, n_tracks, th,
                                    var_idx=0, calib_iters=2,
                                    block_e=64, block_t=32)
    mask_r, var_r = event_filter_ref(
        scalars, tracks, n_tracks, var_idx=0, scalar_thresh=40.0,
        pt_thresh=15.0, min_count=2.0, sum_cap=800.0, calib_iters=2)
    np.testing.assert_array_equal(np.asarray(mask), np.asarray(mask_r))
    np.testing.assert_allclose(np.asarray(var), np.asarray(var_r))


def test_default_interpret_env_override(monkeypatch):
    import repro.kernels as K
    monkeypatch.setenv(K.INTERPRET_ENV, "interpret")
    assert K.default_interpret() is True
    assert K.resolve_interpret(None) is True
    monkeypatch.setenv(K.INTERPRET_ENV, "compiled")
    assert K.default_interpret() is False
    assert K.resolve_interpret(None) is False
    # explicit flags always win over the environment
    assert K.resolve_interpret(True) is True
    monkeypatch.setenv(K.INTERPRET_ENV, "auto")
    # auto = backend probe (CPU test runners -> interpreter)
    assert K.default_interpret() == (jax.default_backend()
                                     not in K.COMPILED_BACKENDS)
    monkeypatch.setenv(K.INTERPRET_ENV, "bogus")
    with pytest.raises(ValueError, match="REPRO_INTERPRET"):
        K.default_interpret()


def test_autotune_block_shapes_caches_and_beats_default():
    from repro.kernels.event_filter import tune as ef_tune
    scalars, tracks, n_tracks = _ef_operands(n=96, t=48)
    th = jnp.array([[40.0, -jnp.inf], [15.0, 15.0], [2.0, 2.0],
                    [-1.0, -1.0]], jnp.float32)
    cache = {}
    tuned = ef_tune.autotune_block_shapes(
        scalars, tracks, n_tracks, th, var_idx=(0, 0), calib_iters=2,
        repeats=2, cache=cache)
    assert tuned.speedup_vs_default >= 1.0
    assert tuned.roofline["gbytes_per_s"] > 0
    # candidates that clamp to the same effective shape timed only once
    effective = {(min(be, 96), min(bt, 48))
                 for be, bt in ef_tune.CANDIDATES}
    assert len(tuned.measurements) == len(effective)
    # second call with the same shape class is a pure cache hit
    again = ef_tune.autotune_block_shapes(
        scalars, tracks, n_tracks, th, var_idx=(0, 0), calib_iters=2,
        repeats=2, cache=cache)
    assert again is tuned and len(cache) == 1


# ------------------------------ rglru scan ------------------------------- #
@pytest.mark.parametrize("b,s,w,bb,bs,bw", [
    (2, 64, 32, 2, 16, 32),
    (3, 100, 48, 2, 32, 16),   # partial blocks everywhere
    (1, 256, 128, 1, 256, 128),  # single chunk
])
def test_rglru_scan_sweep(b, s, w, bb, bs, bw):
    a = jnp.asarray(RNG.uniform(0.7, 0.999, size=(b, s, w)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(b, s, w)), jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(b, w)), jnp.float32)
    y, hl = rglru_scan_pallas(a, x, h0, block_b=bb, block_s=bs, block_w=bw)
    yr, hlr = rglru_scan_ref(a, x, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hlr),
                               rtol=1e-5, atol=1e-5)


def test_rglru_scan_no_h0():
    a = jnp.asarray(RNG.uniform(0.5, 0.99, size=(2, 37, 24)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 37, 24)), jnp.float32)
    y, _ = rglru_scan_pallas(a, x, block_b=2, block_s=8, block_w=8)
    yr, _ = rglru_scan_ref(a, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)


def test_rglru_matches_model_block():
    """Kernel-backed op == the model's associative-scan block output."""
    from repro.configs.registry import reduced_config
    from repro.kernels.rglru_scan.ops import rglru_scan as krn
    from repro.models import rglru as m
    from repro.models.params import ParamTable

    cfg = reduced_config("recurrentgemma-9b")
    t = ParamTable(cfg)
    m.add_recurrent_params(t, cfg, "rec", None)
    p = t.init(jax.random.key(0))["rec"]
    x = jnp.asarray(RNG.normal(size=(2, 48, cfg.lru_width)), jnp.float32)
    y_k, h_k = krn(p, x)
    y_m, h_m = m.rglru_scan(p, x)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_m),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_k), np.asarray(h_m),
                               rtol=1e-4, atol=1e-4)


# ------------------------------ mlstm ------------------------------------ #
@pytest.mark.parametrize("b,s,h,d,bq,bk", [
    (1, 64, 2, 16, 16, 16),
    (2, 96, 4, 32, 32, 16),   # partial blocks
    (1, 128, 1, 64, 64, 64),
])
def test_mlstm_sweep(b, s, h, d, bq, bk):
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    log_i = jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32)
    log_f = jnp.asarray(-np.abs(RNG.normal(size=(b, s, h))) * 0.5,
                        jnp.float32)
    out = mlstm_pallas(q, k, v, log_i, log_f, block_q=bq, block_k=bk)
    ref = mlstm_ref(q, k, v, log_i, log_f, chunk_size=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_mlstm_matches_recurrent_decode():
    """Chunkwise kernel at position t == sequential recurrent state decode
    (the two mLSTM formulations must agree)."""
    from repro.configs.registry import reduced_config
    from repro.models import xlstm as xm
    from repro.models.params import ParamTable
    from repro.parallel.sharding import Sharder
    from repro.launch.mesh import make_mesh_of

    cfg = reduced_config("xlstm-350m")
    t = ParamTable(cfg)
    xm._add_mlstm(t, cfg, "m", 1)
    p = jax.tree.map(lambda a: a[0], t.init(jax.random.key(0))["m"])
    mesh = make_mesh_of((1, 1), ("data", "model"))
    shd = Sharder(cfg, mesh)

    b, s = 2, 12
    x = jnp.asarray(RNG.normal(size=(b, s, cfg.d_model)) * 0.1, jnp.float32)
    y_par = xm.mlstm_block(cfg, p, x, shd)

    d, inner, h, hd, _ = xm._dims(cfg)
    state = {"C": jnp.zeros((b, h, hd, hd)), "n": jnp.zeros((b, h, hd)),
             "m": jnp.full((b, h), -1e30),
             "conv": jnp.zeros((b, cfg.conv1d_width - 1, inner))}
    outs = []
    for i in range(s):
        y_i, state = xm.mlstm_decode(cfg, p, x[:, i:i + 1], state, shd)
        outs.append(y_i)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               rtol=2e-3, atol=2e-3)
