"""Property-based tests (hypothesis) on system invariants."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs.geps_events import reduced
from repro.core import events as ev
from repro.core import merge as merge_lib
from repro.core import query as query_lib
from repro.core.brick import create_store, gather_store
from repro.core.catalog import MetadataCatalog
from repro.core.packets import AdaptivePacketScheduler
from repro.core.replication import (failover_owner, place_replicas,
                                    rereplication_plan)
from repro.parallel.collectives import dequantize_int8, quantize_int8

SCHEMA = ev.EventSchema.from_config(reduced())
SETTINGS = dict(max_examples=30, deadline=None)


# --------------- query compiler: predicate semantics ---------------- #
@settings(**SETTINGS)
@given(th=st.floats(0, 200), n=st.integers(4, 64), seed=st.integers(0, 999))
def test_query_threshold_matches_numpy(th, n, seed):
    rng = np.random.default_rng(seed)
    batch = ev.host_events(rng, SCHEMA, n)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    fn = query_lib.compile_query(f"e_total > {th}", SCHEMA)
    mask = np.asarray(fn(jb)) != 0
    np.testing.assert_array_equal(mask, batch["scalars"][:, 0] > th)


@settings(**SETTINGS)
@given(t1=st.floats(1, 100), t2=st.floats(1, 100), seed=st.integers(0, 99))
def test_query_monotone_in_threshold(t1, t2, seed):
    """Raising a '>' threshold can only shrink the selection."""
    lo, hi = sorted((t1, t2))
    rng = np.random.default_rng(seed)
    batch = ev.host_events(rng, SCHEMA, 48)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    n_lo = float(query_lib.compile_query(f"e_total > {lo}", SCHEMA)(jb).sum())
    n_hi = float(query_lib.compile_query(f"e_total > {hi}", SCHEMA)(jb).sum())
    assert n_hi <= n_lo


@settings(**SETTINGS)
@given(seed=st.integers(0, 999))
def test_query_and_is_intersection(seed):
    rng = np.random.default_rng(seed)
    batch = ev.host_events(rng, SCHEMA, 48)
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    a = query_lib.compile_query("e_total > 40", SCHEMA)(jb) != 0
    b = query_lib.compile_query("n_tracks >= 3", SCHEMA)(jb) != 0
    ab = query_lib.compile_query("e_total > 40 && n_tracks >= 3",
                                 SCHEMA)(jb) != 0
    np.testing.assert_array_equal(np.asarray(ab), np.asarray(a & b))


# --------------- merge: associativity / partition invariance --------- #
@settings(**SETTINGS)
@given(seed=st.integers(0, 999),
       cuts=st.lists(st.integers(1, 99), min_size=0, max_size=6))
def test_merge_partition_invariant(seed, cuts):
    """Any partition of the events into bricks merges to the same result."""
    rng = np.random.default_rng(seed)
    n = 100
    mask = rng.integers(0, 2, n)
    var = rng.uniform(0, 500, n).astype(np.float32)
    ids = np.arange(n)
    whole = merge_lib.from_mask(mask, var, ids)
    bounds = sorted(set([0, n] + [c % n for c in cuts]))
    parts = [merge_lib.from_mask(mask[a:b], var[a:b], ids[a:b])
             for a, b in zip(bounds[:-1], bounds[1:]) if b > a]
    merged = merge_lib.tree_merge(parts)
    assert merged.n_selected == whole.n_selected
    assert np.isclose(merged.sum_var, whole.sum_var, rtol=1e-5)
    np.testing.assert_array_equal(merged.hist, whole.hist)


# --------------- packets: work conservation under failures ----------- #
@settings(**SETTINGS)
@given(n_nodes=st.integers(2, 8), total=st.integers(1, 500),
       kill=st.integers(0, 7), seed=st.integers(0, 99))
def test_packets_conserve_work_under_failure(n_nodes, total, kill, seed):
    cat = MetadataCatalog(n_nodes)
    rng = np.random.default_rng(seed)
    for n in range(n_nodes):
        cat.node(n).throughput_ema = float(rng.uniform(0.3, 3.0))
    sched = AdaptivePacketScheduler(cat, base_packet=32)
    sched.add_work(0, total)
    done = 0
    killed = False
    step = 0
    while not sched.exhausted:
        for node in cat.alive_nodes():
            pkt = sched.next_packet(node)
            if pkt is None:
                continue
            if not killed and kill < n_nodes and node == kill and step > 2:
                sched.fail(pkt.packet_id, node_dead=True)
                killed = True
                break
            sched.complete(pkt.packet_id, pkt.size, 0.01 * pkt.size)
            done += pkt.size
            step += 1
        if len(cat.alive_nodes()) == 0:
            break
    if cat.alive_nodes():
        assert done == total  # exactly-once processing


# --------------- replication invariants ------------------------------ #
@settings(**SETTINGS)
@given(n_nodes=st.integers(2, 16), repl=st.integers(1, 4),
       bid=st.integers(0, 100))
def test_replicas_never_on_primary(n_nodes, repl, bid):
    node = bid % n_nodes
    reps = place_replicas(bid, node, n_nodes, repl)
    assert node not in reps
    assert len(set(reps)) == len(reps)
    assert len(reps) == min(repl - 1, n_nodes - 1)


@settings(**SETTINGS)
@given(n_nodes=st.integers(3, 10), seed=st.integers(0, 99))
def test_rereplication_restores_coverage(n_nodes, seed):
    store = create_store(SCHEMA, n_events=64, n_nodes=n_nodes,
                         events_per_brick=8, replication=2, seed=seed)
    rng = np.random.default_rng(seed)
    dead = {int(rng.integers(0, n_nodes))}
    plan = rereplication_plan(store.specs, dead, n_nodes)
    for bid, src, dst in plan:
        assert src not in dead and dst not in dead
        spec = store.specs[bid]
        spec.replicas = spec.replicas + (dst,)
    for bid in store.specs:
        owners = set(store.owners(bid)) - dead
        assert len(owners) >= min(2, n_nodes - len(dead))


# --------------- numerics ------------------------------------------- #
@settings(**SETTINGS)
@given(seed=st.integers(0, 999), scale=st.floats(1e-3, 1e3))
def test_quantize_roundtrip_error_bound(seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=(64,)) * scale).astype(np.float32))
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.5 + 1e-9 * scale


@settings(**SETTINGS)
@given(seed=st.integers(0, 999))
def test_attention_output_is_convex_combination(seed):
    """Causal softmax attention outputs lie inside the convex hull of V."""
    from repro.kernels.flash_attention.kernel import flash_attention
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 32, 2, 16)), jnp.float32)
    out = np.asarray(flash_attention(q, k, v, causal=True,
                                     block_q=16, block_k=16))
    vmax = np.asarray(v).max()
    vmin = np.asarray(v).min()
    assert out.max() <= vmax + 1e-4 and out.min() >= vmin - 1e-4
