"""Per-architecture smoke tests: REDUCED same-family configs, one forward
and one train step on CPU, asserting output shapes and no NaNs; plus one
decode step exercising each family's cache machinery."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES
from repro.configs.registry import list_archs, reduced_config
from repro.launch.mesh import make_mesh_of
from repro.models import model_zoo
from repro.optim.adamw import AdamW, init_opt_state
from repro.parallel.sharding import Sharder
from repro.train import steps as steps_lib

ARCHS = list_archs()


def _setup(arch, **over):
    cfg = reduced_config(arch, **over)
    mesh = make_mesh_of((1, 1), ("data", "model"))
    model = model_zoo.build_model(cfg)
    params = model.table.init(jax.random.key(0))
    shd = Sharder(cfg, mesh)
    return cfg, mesh, model, params, shd


def _batch(cfg, model, shd, b, s):
    batch = {
        "tokens": jax.random.randint(jax.random.key(1), (b, s), 0,
                                     cfg.vocab_size, jnp.int32),
        "labels": jax.random.randint(jax.random.key(2), (b, s), 0,
                                     cfg.vocab_size, jnp.int32),
    }
    if cfg.num_patches:
        batch["patch_embeds"] = jax.random.normal(
            jax.random.key(3), (b, cfg.num_patches, cfg.d_model),
            jnp.dtype(cfg.dtype))
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            jax.random.key(4), (b, cfg.encoder_seq_len, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg, mesh, model, params, shd = _setup(arch)
    b, s = 2, 32
    batch = _batch(cfg, model, shd, b, s)
    logits, aux = model.forward(params, batch, shd)
    assert logits.shape == (b, s, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"
    assert not bool(jnp.isnan(aux)), f"{arch}: NaN aux loss"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    cfg, mesh, model, params, shd = _setup(arch, microbatches=2)
    b, s = 4, 32
    batch = _batch(cfg, model, shd, b, s)
    step_fn, _ = steps_lib.make_train_step(cfg, model, mesh)
    opt_state = init_opt_state(params, AdamW())
    p2, o2, metrics = jax.jit(step_fn)(params, opt_state, batch)
    assert float(metrics["loss"]) > 0
    assert not bool(jnp.isnan(metrics["loss"])), f"{arch}: NaN loss"
    assert not bool(jnp.isnan(metrics["grad_norm"])), f"{arch}: NaN grads"
    # params actually changed
    d0 = jax.tree.leaves(params)[0]
    d1 = jax.tree.leaves(p2)[0]
    assert d0.shape == d1.shape


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step(arch):
    cfg, mesh, model, params, shd = _setup(arch)
    b = 2
    cache = model.init_cache(shd, b, 64)
    dec, _ = steps_lib.make_decode_step(cfg, model, mesh)
    tok = jnp.ones((b, 1), jnp.int32)
    jd = jax.jit(dec)
    logits, cache = jd(params, cache, {"tokens": tok})
    logits2, cache = jd(params, cache, {"tokens": tok})
    assert logits.shape == (b, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN decode logits"
    assert not bool(jnp.isnan(logits2).any())
    assert int(cache["t"]) == 2
